"""Differential self-test: every CommStep kind, simulator vs real devices.

Run as a module under the forced-host-device harness::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.runtime.selftest

For 2/4/8 virtual devices it builds an annotation pair that resolves to
each operator kind (ID, SR, AR, RS, AG, SplitAR, SplitRS, SplitAG, BSR,
Slice), executes the plan bit-differentially against the simulator, and
additionally checks: the fast psum reduction path (integer shards), the
paper's Fig 9 heterogeneous multi-step stage, resharding round-trips, the
dynamic-switch weight migration through the fused-BSR path on the jax
backend, the microbatched pipeline schedules (``api:pipeline/*``:
1F1B/GPipe over 2 stages, and ``api:pipeline/interleaved*``: Megatron's
v=2 virtual-stage schedule over a zigzag plan), the async MPMD executor
(``async:pipeline/*`` and ``async:train/4``: per-stage programs with
double-buffered P2P and eager grad-reduce, bitwise vs both executors),
and the automated strategy search's execution validation
(``repro.search`` top-3 on a hetero CPU fixture), all bit-exact sim vs
jax.  Emits one
machine-readable line: ``RUNTIME_SELFTEST_JSON {...}``
(consumed by ``tests/test_runtime.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys
import traceback

from repro.runtime.harness import FORCE_FLAG, ensure_host_devices

_ap = argparse.ArgumentParser()
_ap.add_argument("--devices", type=int, default=None,
                 help="forced host device count; the sweep covers the "
                      "2/4/8 tiers that fit (CI runs --devices 4). "
                      "Defaults to an XLA_FLAGS force-count already in "
                      "the environment (the harness's n_devices), else 8")
_ARGS, _ = _ap.parse_known_args()
if _ARGS.devices is None:
    m = re.search(re.escape(FORCE_FLAG) + r"=(\d+)",
                  os.environ.get("XLA_FLAGS", ""))
    _ARGS.devices = int(m.group(1)) if m else 8
if _ARGS.devices < 2:
    _ap.error("--devices must be >= 2 (the smallest sweep tier)")

ensure_host_devices(_ARGS.devices)  # must precede any jax import

import numpy as np  # noqa: E402

from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd  # noqa: E402

SHAPE = (16, 8)
KINDS = ("ID", "SR", "AR", "RS", "AG", "SplitAR", "SplitRS", "SplitAG",
         "BSR", "Slice")


def kind_cases(n: int) -> dict[str, tuple[HSPMD, HSPMD]]:
    """(src, dst) pairs over n devices resolving to each operator kind."""
    devs = list(range(n))
    half = n // 2
    g0, g1 = devs[:half], devs[half:]
    row = DS({0: half}) if half > 1 else DS({})
    col = DS({1: half}) if half > 1 else DS({})
    return {
        "ID": (spmd(devs, DS({0: n})), spmd(devs, DS({0: n}))),
        "SR": (spmd(devs, DS({0: n})),
               spmd(list(reversed(devs)), DS({0: n}))),
        "AR": (spmd(devs, DS({PARTIAL: n})), spmd(devs, DS({DUP: n}))),
        "RS": (spmd(devs, DS({PARTIAL: n})), spmd(devs, DS({0: n}))),
        "AG": (spmd(devs, DS({0: n})), spmd(devs, DS({DUP: n}))),
        "BSR": (spmd(devs, DS({0: n})), spmd(devs, DS({1: n}))),
        "SplitAR": (HSPMD([g0, g1], [row, row], hdim=PARTIAL),
                    HSPMD([g0, g1], [row, row], hdim=DUP)),
        "SplitRS": (HSPMD([g0, g1], [row, row], hdim=PARTIAL),
                    HSPMD([g0, g1], [row, row], hdim=0)),
        "SplitAG": (HSPMD([g0, g1], [row, row], hdim=0),
                    HSPMD([g0, g1], [row, row], hdim=DUP)),
        "Slice": (HSPMD([g0, g1], [col, col], hdim=DUP),
                  HSPMD([g0, g1], [col, col], hdim=0)),
    }


def fig9_plan():
    """The paper's Fig 9 CommOp id=2: RS + BSR + ID in one stage."""
    from repro.core.graph import Graph
    from repro.core.specialize import resolve_comm_ops

    g = Graph()
    x_annot = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                    dss=[DS({2: 2}), DS({0: 2}), DS({})], hdim=0)
    w_dup = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                  dss=[DS({DUP: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    w_tp = HSPMD(dgs=[[0, 3], [2, 4], [1]],
                 dss=[DS({0: 2}), DS({DUP: 2}), DS({})], hdim=DUP)
    x = g.placeholder("X", (12, 16, 32), [x_annot])
    w = g.parameter("W", (32, 64), [w_dup])
    x2 = g.gelu(x)
    w2 = g.comm(w, w_tp)
    y = g.dot(x2, w2, name="Y")
    y_next = HSPMD(dgs=[[0, 3], [5, 6], [1]],
                   dss=[DS({0: 2}), DS({1: 2}), DS({})], hdim=0)
    g.comm(y, y_next, name="Y2")
    g.deduce()
    rc = resolve_comm_ops(g)[1]
    return rc.plan, tuple(rc.op.inputs[0].shape)


def run_all(max_devices: int = 8) -> dict:
    from repro.launch.mesh import make_runtime_mesh
    from repro.runtime.diff import (differential_check, integer_decompose,
                                    roundtrip_check)

    report: dict = {"cases": {}}

    def record(key, fn):
        try:
            extra = fn() or {}
            report["cases"][key] = {"ok": True, **extra}
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            report["cases"][key] = {
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc(limit=8)}

    meshes = {n: make_runtime_mesh(n) for n in (2, 4, 8)
              if n <= max_devices}
    big = max(meshes)
    rng = np.random.default_rng(0)
    value = rng.normal(size=SHAPE).astype(np.float32)
    ivalue = rng.integers(-8, 9, size=SHAPE).astype(np.float32)

    # 1. the kind sweep: exact differential equivalence on 2/4/8 devices
    for n, mesh in meshes.items():
        cases = kind_cases(n)
        assert set(cases) == set(KINDS), sorted(set(cases) ^ set(KINDS))
        for kind in KINDS:
            src, dst = cases[kind]
            def case(kind=kind, src=src, dst=dst, mesh=mesh):
                plan = differential_check(value, src, dst, mesh)
                kinds = [s.kind for s in plan.steps]
                assert kind in kinds, (kind, kinds, plan.kind)
                return {"plan_kind": plan.kind, "step_kinds": kinds}
            record(f"{kind}/{n}", case)

    # 2. fast psum reduction path (integer shards => order-insensitive)
    for kind in ("AR", "RS", "SplitAR", "SplitRS"):
        src, dst = kind_cases(big)[kind]
        def fast(src=src, dst=dst):
            plan = differential_check(
                ivalue, src, dst, meshes[big], reduction="fast",
                decompose=integer_decompose)
            return {"step_kinds": [s.kind for s in plan.steps]}
        record(f"fast:{kind}/{big}", fast)

    # 3. heterogeneous extras: non-uniform hsplits + Fig 9 multi-step stage
    def hsplits_case():
        src = HSPMD(dgs=[[0, 1], [2, 3]], dss=[DS({DUP: 2}), DS({0: 2})],
                    hdim=0, hsplits=[1, 3])
        dst = spmd([0, 1, 2, 3], DS({0: 4}))
        plan = differential_check(value, src, dst, meshes[4])
        return {"plan_kind": plan.kind}
    if 4 in meshes:
        record("hetero:hsplits/4", hsplits_case)

    def fig9_case():
        plan, shape = fig9_plan()
        v = np.asarray(rng.normal(size=shape), np.float32)
        differential_check(v, plan.src, plan.dst, meshes[8], plan=plan)
        return {"plan_kind": plan.kind,
                "step_kinds": [s.kind for s in plan.steps]}
    if 8 in meshes:
        record("hetero:fig9/7", fig9_case)

    # 4. resharding round-trips (src -> dst -> src restores the shards)
    for n, mesh in meshes.items():
        def rt_split(n=n, mesh=mesh):
            roundtrip_check(value, spmd(range(n), DS({0: n})),
                            spmd(range(n), DS({1: n})), mesh)
        record(f"roundtrip:split/{n}", rt_split)
    def rt_hetero():
        half = [0, 1], [2, 3]
        src = HSPMD(list(half), [DS({0: 2}), DS({0: 2})], hdim=0)
        dst = spmd([0, 1, 2, 3], DS({DUP: 4}))
        roundtrip_check(value, src, dst, meshes[4])
    if 4 in meshes:
        record("roundtrip:hetero/4", rt_hetero)

    # 5. dynamic-switch weight migration through the fused-BSR path
    def switch_case():
        from repro.core.graph import Graph
        from repro.core.simulator import scatter
        from repro.core.switching import execute_switch

        g = Graph()
        s0_w1 = spmd([0, 1, 2, 3], DS({1: 4}))
        s1_w1 = spmd([4, 5, 6, 7], DS({DUP: 4}))
        s0_w2 = spmd([0, 1, 2, 3], DS({0: 4}))
        s1_w2 = spmd([4, 5, 6, 7], DS({DUP: 4}))
        g.placeholder("X", (8, 16, 32),
                      [spmd([0, 1, 2, 3], DS({DUP: 4})),
                       spmd([4, 5, 6, 7], DS({0: 4}))])
        w1 = g.parameter("W1", (32, 64), [s0_w1, s1_w1])
        w2 = g.parameter("W2", (64, 32), [s0_w2, s1_w2])
        h = g.dot(g.tensors["X"], w1)
        g.dot(g.gelu(h), w2)
        g.deduce()

        srng = np.random.default_rng(3)
        values = {p.name: srng.normal(size=p.shape).astype(np.float32)
                  for p in g.parameters()}
        weights = {name: scatter(v, g.tensors[name].annots[0])
                   for name, v in values.items()}
        real = execute_switch(weights, g, 0, 1, backend="jax",
                              mesh=meshes[8])
        sim = execute_switch(weights, g, 0, 1, backend="sim")
        for name, v in values.items():
            dst = g.tensors[name].annots[1]
            for dev in dst.devices:
                box = dst.device_box(dev, v.shape)
                want = v[tuple(slice(lo, hi) for lo, hi in box)]
                np.testing.assert_array_equal(real[name].parts[dev], want)
                np.testing.assert_array_equal(real[name].parts[dev],
                                              sim[name].parts[dev])
        # and back: jax-backend migration is reversible
        back = execute_switch(real, g, 1, 0, backend="jax", mesh=meshes[8])
        for name in values:
            for dev, arr in weights[name].parts.items():
                np.testing.assert_array_equal(back[name].parts[dev], arr)
    if 8 in meshes:
        record("switch:jax/8", switch_case)

    # 6. repro.api Session parity: a specialized pipeline stage's compute
    #    + comm ExecItems end-to-end, SimulatorExecutor vs JaxExecutor
    for n, mesh in meshes.items():
        def session_case(n=n, mesh=mesh):
            from repro import api

            half = n // 2
            s0, s1 = list(range(half)), list(range(half, n))
            g = api.Graph()
            g.placeholder("X", (8, 16))
            g.parameter("W1", (16, 12))
            h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
                       name="H")
            g.comm(h, name="H2")
            g.parameter("W2", (12, 6))
            g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")

            col = DS({1: half}) if half > 1 else DS({})
            row = DS({0: half}) if half > 1 else DS({})
            strat = api.Strategy(f"pipe{n}", {
                "X": spmd(s0, DS({DUP: half})),
                "W1": spmd(s0, col),
                "H2": spmd(s1, row),
                "W2": spmd(s1, DS({DUP: half})),
            })
            prog = api.Program(g, [strat])

            srng = np.random.default_rng(7)
            xv = srng.integers(-4, 5, (8, 16)).astype(np.float32)
            w1v = srng.integers(-4, 5, (16, 12)).astype(np.float32)
            w2v = srng.integers(-4, 5, (12, 6)).astype(np.float32)
            want = np.maximum(xv @ w1v, 0) @ w2v

            outs = {}
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
                sess = api.Session(prog, f"pipe{n}", executor=ex)
                sess.load({"W1": w1v, "W2": w2v})
                res = sess.run({"X": xv})
                np.testing.assert_array_equal(res.value("Y"), want)
                outs[ex.name] = res.shards("Y")
            for dev, arr in outs["sim"].parts.items():
                np.testing.assert_array_equal(
                    outs["jax"].parts[dev], arr,
                    err_msg=f"dev {dev}: jax executor differs from sim")
            # the per-device programs really interleave compute and comm
            plan = prog.compile(f"pipe{n}")
            kinds = {i.role for d in plan.devices
                     for i in plan.exec_items(d)}
            assert kinds == {"compute", "comm"}, kinds
            return {"devices": len(plan.devices)}
        record(f"api:session/{n}", session_case)

    # 7. microbatched pipeline schedules: Session.run(num_microbatches=m)
    #    over a 2-stage loss-accumulating pipeline — per-microbatch shards
    #    bit-exact sim vs jax (the jax side scans ONE shard_map program
    #    over the microbatch axis), fetches bit-identical across
    #    m in {1,2,4} (integer data makes the loss sums exact), GPipe ==
    #    1F1B bitwise, and the timetable matches the analytic fill/drain
    #    count
    for n, mesh in meshes.items():
        def pipeline_case(n=n, mesh=mesh):
            from repro import api
            from repro.core.costmodel import fill_drain_count

            half = n // 2
            s0, s1 = list(range(half)), list(range(half, n))
            g = api.Graph()
            g.placeholder("X", (16, 16))
            g.parameter("W1", (16, 12))
            h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"),
                       name="H")
            g.comm(h, name="H2")
            g.parameter("W2", (12, 6))
            y = g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")
            g.sum(g.sum(y, 1, name="L1"), 0, name="L")

            col = DS({1: half}) if half > 1 else DS({})
            row = DS({0: half}) if half > 1 else DS({})
            strat = api.Strategy(f"pipe{n}", {
                "X": spmd(s0, DS({DUP: half})),
                "W1": spmd(s0, col),
                "H2": spmd(s1, row),
                "W2": spmd(s1, DS({DUP: half})),
            })
            prog = api.Program(g, [strat])

            srng = np.random.default_rng(11)
            xv = srng.integers(-4, 5, (16, 16)).astype(np.float32)
            w1v = srng.integers(-4, 5, (16, 12)).astype(np.float32)
            w2v = srng.integers(-4, 5, (12, 6)).astype(np.float32)
            want_y = np.maximum(xv @ w1v, 0) @ w2v
            want_l = want_y.sum()

            results = {}
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
                sess = api.Session(prog, f"pipe{n}", executor=ex)
                sess.load({"W1": w1v, "W2": w2v})
                for m in (1, 2, 4):
                    r = sess.run({"X": xv}, fetches=["Y", "L"],
                                 num_microbatches=m)
                    # bit-identical across m: integer-exact loss sums
                    assert float(r.value("L")) == float(want_l), \
                        (ex.name, m, float(r.value("L")), float(want_l))
                    np.testing.assert_array_equal(r.value("Y"), want_y)
                    results[(ex.name, m)] = r
                    # interleaved at v=1 degenerates to the same table:
                    # bit-identical to 1F1B for every m
                    ri = sess.run({"X": xv}, fetches=["Y", "L"],
                                  num_microbatches=m,
                                  schedule="interleaved")
                    for name in ("Y", "L"):
                        a = r.shards(name)
                        b = ri.shards(name)
                        for dev in a.parts:
                            np.testing.assert_array_equal(
                                b.parts[dev], a.parts[dev],
                                err_msg=f"{name} m={m}: interleaved "
                                        f"differs from 1f1b ({ex.name})")
                rg = sess.run({"X": xv}, fetches=["Y", "L"],
                              num_microbatches=4, schedule="gpipe")
                results[(ex.name, "gpipe")] = rg
            for m in (2, 4, "gpipe"):
                for name in ("Y", "L"):
                    a = results[("sim", m)].shards(name)
                    b = results[("jax", m)].shards(name)
                    for dev in a.parts:
                        np.testing.assert_array_equal(
                            b.parts[dev], a.parts[dev],
                            err_msg=f"{name} m={m} dev {dev}: jax "
                                    f"differs from sim")
            for ex in ("sim", "jax"):  # GPipe == 1F1B bitwise
                for name in ("Y", "L"):
                    a = results[(ex, 4)].shards(name)
                    b = results[(ex, "gpipe")].shards(name)
                    for dev in a.parts:
                        np.testing.assert_array_equal(b.parts[dev],
                                                      a.parts[dev])
            plan = prog.compile(f"pipe{n}")
            sched = results[("sim", 4)].schedule
            assert sched.fill_drain_slots == \
                fill_drain_count(4, plan.n_stages), \
                (sched.fill_drain_slots, plan.n_stages)
            # priced timetable reproduces the uniform closed form
            assert sched.stats().makespan == float(
                2 * fill_drain_count(4, plan.n_stages))
            return {"n_stages": plan.n_stages,
                    "slots": sched.n_slots,
                    "bubbles": sched.stats().bubbles}
        record(f"api:pipeline/{n}", pipeline_case)

    # 7b. interleaved virtual-stage 1F1B: a plan whose dataflow crosses
    #     the 2-device stage boundary three times (s0 -> s1 -> s0 -> s1,
    #     Megatron's v=2 chunk layout).  The simulator interprets the
    #     virtual-stage timetable tick by tick; the jax executor scans
    #     the same zigzag graph in ONE shard_map program — bit-exact per
    #     microbatch, and bit-identical to the unpipelined run across
    #     m in {1,2,4} (integer-exact data)
    for n, mesh in meshes.items():
        def interleaved_case(n=n, mesh=mesh):
            from repro import api
            from repro.api.testing import zigzag_program, zigzag_values

            prog = zigzag_program(n, name=f"zig{n}")
            plan = prog.compile(f"zig{n}")
            assert plan.n_stages == 2, plan.n_stages
            assert plan.virtual_stages_per_device == 2

            xv, ws, want_y = zigzag_values(seed=13)

            results = {}
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
                sess = api.Session(prog, f"zig{n}", executor=ex)
                sess.load(ws)
                for m in (1, 2, 4):
                    r = sess.run({"X": xv}, fetches=["Y", "L"],
                                 num_microbatches=m,
                                 schedule="interleaved")
                    np.testing.assert_array_equal(r.value("Y"), want_y)
                    assert float(r.value("L")) == float(want_y.sum())
                    results[(ex.name, m)] = r
                # the wrapped plan refuses flat schedules
                try:
                    sess.run({"X": xv}, num_microbatches=2,
                             schedule="1f1b")
                except api.ScheduleError:
                    pass
                else:
                    raise AssertionError("1f1b accepted a v=2 plan")
            for m in (2, 4):
                for name in ("Y", "L"):
                    a = results[("sim", m)].shards(name)
                    b = results[("jax", m)].shards(name)
                    for dev in a.parts:
                        np.testing.assert_array_equal(
                            b.parts[dev], a.parts[dev],
                            err_msg=f"{name} m={m} dev {dev}: jax "
                                    f"differs from sim (interleaved)")
            sched = results[("sim", 4)].schedule
            assert sched.virtual_per_stage == 2
            assert sched.n_virtual == 4
            # the jax program deduces the same chunk structure
            lw = api.JaxExecutor(mesh).lowered(
                prog.compile_micro(f"zig{n}", 4), ["Y", "L"],
                num_microbatches=4)
            assert lw.n_virtual_stages == 4, lw.n_virtual_stages
            return {"v": sched.virtual_per_stage,
                    "slots": sched.n_slots,
                    "bubble_fraction": sched.stats().bubble_fraction}
        record(f"api:pipeline/interleaved{n}", interleaved_case)

    # 7c. end-to-end sharded TRAINING steps: Session.train_step compiles
    #     the joint fwd+bwd plan (real backward ExecItems; bwd ticks of
    #     the timetable execute gradient compute + grad-reduce comm) and
    #     applies sharded AdamW — losses, gradient shards and updated
    #     weight shards bit-exact sim vs jax and bit-identical across
    #     m in {1,2,4} x {1f1b, gpipe} (integer-valued leaves)
    for n, mesh in meshes.items():
        def train_case(n=n, mesh=mesh):
            from repro import api
            from repro.api.testing import (loss_pipeline_program,
                                           loss_pipeline_values)

            prog = loss_pipeline_program(n, name=f"pipe{n}")
            xv, ws, want_y = loss_pipeline_values(seed=11)
            want_loss = float(want_y.sum())

            runs = {}
            for m, kind in [(1, "1f1b"), (2, "1f1b"), (4, "1f1b"),
                            (4, "gpipe")]:
                for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
                    sess = api.Session(prog, f"pipe{n}", executor=ex)
                    sess.load(ws)
                    r = sess.train_step({"X": xv}, num_microbatches=m,
                                        schedule=kind)
                    assert r.loss == want_loss, (ex.name, m, kind, r.loss)
                    runs[(ex.name, m, kind)] = (
                        r, {w: sess.weights[w] for w in ws})
            base, base_w = runs[("sim", 1, "1f1b")]
            for (exn, m, kind), (r, w) in runs.items():
                for name in ws:
                    a, b = base.grads[name], r.grads[name]
                    for dev in a.parts:
                        np.testing.assert_array_equal(
                            b.parts[dev], a.parts[dev],
                            err_msg=f"grad {name} dev {dev}: "
                                    f"{exn}/m={m}/{kind} differs")
                    aw, bw = base_w[name], w[name]
                    for dev in aw.parts:
                        np.testing.assert_array_equal(
                            bw.parts[dev], aw.parts[dev],
                            err_msg=f"weight {name} dev {dev}: "
                                    f"{exn}/m={m}/{kind} differs")
            # the bwd ticks really ran backward items on both phases
            tplan = prog.compile_train(f"pipe{n}")
            phases = {i.phase for d in tplan.devices
                      for i in tplan.exec_items(d)}
            assert phases == {"fwd", "bwd"}, phases
            return {"loss": want_loss,
                    "grad_norm": base.metrics["grad_norm"]}
        record(f"api:train/{n}", train_case)

    # 7d. interleaved virtual-stage TRAINING on the zigzag (v=2) plan:
    #     backward ops anchor to their forward chunk's virtual stage, so
    #     the interleaved timetable's bwd ticks drain chunk 1 before
    #     chunk 0 — bit-exact sim vs jax and across m
    for n, mesh in meshes.items():
        def train_interleaved_case(n=n, mesh=mesh):
            from repro import api
            from repro.api.testing import zigzag_program, zigzag_values

            prog = zigzag_program(n, name=f"zig{n}")
            xv, ws, want_y = zigzag_values(seed=13)
            runs = {}
            for m in (1, 2, 4):
                for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh)):
                    sess = api.Session(prog, f"zig{n}", executor=ex)
                    sess.load(ws)
                    r = sess.train_step({"X": xv}, num_microbatches=m,
                                        schedule="interleaved")
                    assert r.loss == float(want_y.sum()), (ex.name, m)
                    runs[(ex.name, m)] = r
            base = runs[("sim", 1)]
            for (exn, m), r in runs.items():
                for name in ws:
                    a, b = base.grads[name], r.grads[name]
                    for dev in a.parts:
                        np.testing.assert_array_equal(
                            b.parts[dev], a.parts[dev],
                            err_msg=f"grad {name} dev {dev}: {exn}/m={m} "
                                    f"differs (interleaved train)")
            return {"loss": base.loss}
        record(f"api:train/interleaved{n}", train_interleaved_case)

    # 7e. hsize>1 TRAINING: the heterogeneous-subgroup fixture's weight
    #     gradients come out hdim=Partial (one summand per subgroup's
    #     batch slab, plus a bottom-tier Partial inside the row-split
    #     subgroup), so the grad-reduce CommOp resolves the full
    #     two-tier reduction (bottom AR then top SplitAR) and BOTH
    #     executors execute it — integer leaves, so losses, gradient
    #     shards and every duplicate copy are bit-exact sim vs jax and
    #     equal to the dense numpy reference
    def train_hetero_case():
        from repro import api
        from repro.api.testing import hetero_program, hetero_values
        from repro.core.comm_resolve import resolve

        prog = hetero_program()
        xv, ws, want_loss, want_grads = hetero_values(seed=7)

        # the compiled grad comms really carry hetero Partial sources
        tplan = prog.compile_train("het", loss="L")
        gg = tplan.graph
        plan_kinds = {}
        for p in ws:
            carrier = gg.tensors[gg.grad_map[p]]
            src = carrier.producer.inputs[0].annots[0]
            assert src.hsize == 2 and src.hdim == PARTIAL, (p, src)
            plan = resolve(src, carrier.annots[0],
                           tuple(carrier.shape))
            assert "SplitAR" in plan.kind, (p, plan.kind)
            plan_kinds[p] = plan.kind

        runs = {}
        for m in (1, 2):
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(meshes[4])):
                sess = api.Session(prog, "het", executor=ex)
                sess.load(ws)
                r = sess.train_step({"X": xv}, num_microbatches=m)
                assert r.loss == want_loss, (ex.name, m, r.loss)
                runs[(ex.name, m)] = r
        base = runs[("sim", 1)]
        for name, want in want_grads.items():
            for dev, part in base.grads[name].parts.items():
                np.testing.assert_array_equal(
                    part, want.astype(np.float32),
                    err_msg=f"hetero grad {name} dev {dev} vs dense ref")
        for (exn, m), r in runs.items():
            for name in ws:
                a, b = base.grads[name], r.grads[name]
                for dev in a.parts:
                    np.testing.assert_array_equal(
                        b.parts[dev], a.parts[dev],
                        err_msg=f"hetero grad {name} dev {dev}: "
                                f"{exn}/m={m} differs")
        return {"loss": want_loss, "grad_comms": plan_kinds}
    if 4 in meshes:
        record("api:train/hetero4", train_hetero_case)

    # 7f. async MPMD executor (``runtime.async_program``): ONE XLA
    #     program per (virtual stage, phase) with double-buffered P2P
    #     channels and grad-reduce issued eagerly after each backward
    #     tick must stay BITWISE equal to the simulator and the scanned
    #     jax program across m x {1f1b, gpipe, interleaved} — overlap
    #     may only reorder independent work, never change a bit
    for n, mesh in meshes.items():
        def async_pipeline_case(n=n, mesh=mesh):
            from repro import api
            from repro.api.testing import (loss_pipeline_program,
                                           loss_pipeline_values)

            prog = loss_pipeline_program(n, name=f"pipe{n}")
            xv, ws, want_y = loss_pipeline_values(seed=11)
            runs = {}
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(mesh),
                       api.AsyncExecutor(mesh)):
                sess = api.Session(prog, f"pipe{n}", executor=ex)
                sess.load(ws)
                for m in (1, 2, 4):
                    for kind in (("1f1b", "gpipe", "interleaved")
                                 if m > 1 else ("1f1b",)):
                        r = sess.run({"X": xv}, fetches=["Y", "L"],
                                     num_microbatches=m, schedule=kind)
                        np.testing.assert_array_equal(r.value("Y"),
                                                      want_y)
                        assert float(r.value("L")) == float(want_y.sum())
                        runs[(ex.name, m, kind)] = r
            # per-device shards bitwise equal across executors at each
            # (m, kind) — L is Partial, so its per-device summands are
            # only comparable at the same microbatching
            for (exn, m, kind), r in runs.items():
                if exn == "sim":
                    continue
                for name in ("Y", "L"):
                    a = runs[("sim", m, kind)].shards(name)
                    b = r.shards(name)
                    for dev in a.parts:
                        np.testing.assert_array_equal(
                            b.parts[dev], a.parts[dev],
                            err_msg=f"{name} dev {dev}: {exn}/m={m}/"
                                    f"{kind} differs from sim (async)")
            # per-stage MPMD really happened: one fwd + one bwd program
            # per virtual stage, and the boundary P2P + grad reduces
            # run as channels, not inside the epilogue
            ax = api.AsyncExecutor(mesh)
            lw = ax.lowered(prog.compile_train(f"pipe{n}"))
            n_virtual = prog.compile(f"pipe{n}").n_stages
            assert len(lw.programs) == 2 * n_virtual, \
                (sorted(lw.programs), n_virtual)
            if n >= 4:      # n=2: 1-device stages -> no partial grads
                assert any(ch.kind == "reduce" for ch in lw.channels), \
                    [ch.kind for ch in lw.channels]
            if n_virtual > 1:
                assert any(ch.kind == "p2p" for ch in lw.channels), \
                    [ch.kind for ch in lw.channels]
            return {"programs": len(lw.programs),
                    "channels": len(lw.channels)}
        record(f"async:pipeline/{n}", async_pipeline_case)

    # 7g. async TRAINING: losses, gradient shards and updated weight
    #     shards bit-exact vs both executors across m and kinds,
    #     including the v=2 interleaved zigzag (virtual stages multiplex
    #     one device's two chunks onto distinct per-chunk programs)
    def async_train_case():
        from repro import api
        from repro.api.testing import (loss_pipeline_program,
                                       loss_pipeline_values,
                                       zigzag_program, zigzag_values)

        prog = loss_pipeline_program(4, name="pipe4")
        xv, ws, want_y = loss_pipeline_values(seed=11)
        want_loss = float(want_y.sum())
        runs = {}
        for m, kind in [(1, "1f1b"), (2, "1f1b"), (4, "1f1b"),
                        (4, "gpipe")]:
            for ex in (api.SimulatorExecutor(), api.JaxExecutor(meshes[4]),
                       api.AsyncExecutor(meshes[4])):
                sess = api.Session(prog, "pipe4", executor=ex)
                sess.load(ws)
                r = sess.train_step({"X": xv}, num_microbatches=m,
                                    schedule=kind)
                assert r.loss == want_loss, (ex.name, m, kind, r.loss)
                runs[(ex.name, m, kind)] = (
                    r, {w: sess.weights[w] for w in ws})
        base, base_w = runs[("sim", 1, "1f1b")]
        for (exn, m, kind), (r, w) in runs.items():
            for name in ws:
                a, b = base.grads[name], r.grads[name]
                for dev in a.parts:
                    np.testing.assert_array_equal(
                        b.parts[dev], a.parts[dev],
                        err_msg=f"grad {name} dev {dev}: {exn}/m={m}/"
                                f"{kind} differs (async train)")
                aw, bw = base_w[name], w[name]
                for dev in aw.parts:
                    np.testing.assert_array_equal(
                        bw.parts[dev], aw.parts[dev],
                        err_msg=f"weight {name} dev {dev}: {exn}/m={m}/"
                                f"{kind} differs (async train)")

        # interleaved v=2 zigzag training through the async path
        zprog = zigzag_program(4, name="zig4")
        zx, zws, zwant_y = zigzag_values(seed=13)
        zruns = {}
        for m in (1, 2, 4):
            for ex in (api.SimulatorExecutor(),
                       api.AsyncExecutor(meshes[4])):
                sess = api.Session(zprog, "zig4", executor=ex)
                sess.load(zws)
                r = sess.train_step({"X": zx}, num_microbatches=m,
                                    schedule="interleaved")
                assert r.loss == float(zwant_y.sum()), (ex.name, m)
                zruns[(ex.name, m)] = r
        zbase = zruns[("sim", 1)]
        for (exn, m), r in zruns.items():
            for name in zws:
                a, b = zbase.grads[name], r.grads[name]
                for dev in a.parts:
                    np.testing.assert_array_equal(
                        b.parts[dev], a.parts[dev],
                        err_msg=f"grad {name} dev {dev}: {exn}/m={m} "
                                f"(async interleaved train)")
        return {"loss": want_loss, "zigzag_loss": zbase.loss}
    if 4 in meshes:
        record("async:train/4", async_train_case)

    # 7h. automated strategy search, execution-validated: the searcher
    #     enumerates/prunes/ranks candidates for a 2-fast + 2-slow CPU
    #     fixture, executes the top-3 as proxy TRAINING programs on both
    #     executors (losses + gradients bit-exact sim vs jax), and the
    #     speed-projected measured-makespan ordering must agree with the
    #     cost model's (at most one discordant pair tolerated — the
    #     makespans come from wall-clock op timings)
    def search_case():
        from repro.search import Searcher, cpu_hetero_cluster, tiny_spec

        searcher = Searcher(tiny_spec(), global_batch=8, seq_len=128,
                            tp_options=(1, 2), pp_options=(1, 2),
                            pipeline_options=(1, 2), virtual_options=(1,))
        result = searcher.search(cpu_hetero_cluster(2, 2), validate_top=3,
                                 executors=("sim", "jax"), mesh=meshes[4],
                                 repeats=5, batch=64, d=64, f=128)
        val = result.validation
        assert val is not None and val.speed_projected
        execed = [e for e in val.executed if e.error is None]
        assert len(execed) == 3, [e.describe() for e in val.executed]
        assert all(e.bit_exact for e in execed), \
            [e.describe() for e in execed]
        ag = val.agreement()
        assert ag is not None and ag >= 2 / 3, val.summary()
        best = result.best.candidate
        assert best.kind == "hetero", best.describe()
        return {"winner": best.name, "agreement": ag,
                "prune": result.prune_report.counts()}
    if 4 in meshes:
        record("search:hetero/4", search_case)

    # 7i. the elastic trace driver: real train_steps through device
    #     loss/join — each 2-transition trace re-selects a strategy for
    #     the surviving ranks and migrates weights AND AdamW m/v
    #     restart-free (Session.switch, fused BSR).  The probe fixture's
    #     weight gradients are weight-independent integers, so the
    #     weights / m / v trajectory must be bitwise equal sim vs jax
    #     AND bitwise equal to an uninterrupted single-strategy
    #     reference run; only the loss (a float activation sum) is
    #     reduction-order-dependent and compares to tolerance
    def elastic_case(trace):
        from repro import api
        from repro.core.simulator import gather as gather_st
        from repro.elastic import ElasticDriver, TraceEvent
        from repro.elastic.fixtures import (probe_feeds, probe_graph,
                                            probe_layout, probe_provider,
                                            probe_values, reference_run)

        def snap(sess):
            out = {n2: gather_st(st)
                   for n2, st in sess.weights.items()}
            for key in ("m", "v"):
                for n2, st in sess.opt_state[key].items():
                    out[f"{key}/{n2}"] = gather_st(st)
            return out

        n_steps = 6
        ref, ref_losses = reference_run(
            probe_layout([0, 1, 2, 3], "dp"), n_steps)
        want = snap(ref)
        kinds = None
        losses = {}
        for ex in (api.SimulatorExecutor(), api.JaxExecutor(meshes[4])):
            drv = ElasticDriver(
                probe_graph(), probe_values(), probe_provider(),
                probe_feeds, executor=ex, num_microbatches=2)
            run = drv.run([TraceEvent(*e) for e in trace], n_steps)
            got = snap(drv.session)
            for k2, a in want.items():
                np.testing.assert_array_equal(
                    got[k2], a, err_msg=f"{ex.name}: {k2} drifted from "
                                        f"the uninterrupted reference")
            np.testing.assert_allclose(run.losses, ref_losses,
                                       rtol=1e-5)
            assert len(run.transitions) == 2, run.summary()
            losses[ex.name] = run.losses
            kinds = run.transition_kinds()
        np.testing.assert_allclose(losses["jax"], losses["sim"],
                                   rtol=1e-5)
        return {"kinds": kinds}
    if 4 in meshes:
        for key, trc in {
            "elastic:trace/4to2": [(0, (0, 1, 2, 3), "dp"),
                                   (2, (0, 1), "dp"),
                                   (4, (0, 1), "pp")],
            "elastic:trace/2to4": [(0, (0, 1), "dp"),
                                   (2, (0, 1, 2, 3), "dp"),
                                   (4, (0, 1, 2, 3), "pp")],
            "elastic:trace/hetero": [(0, (0, 1, 2, 3), "dp"),
                                     (2, (0, 1, 2, 3), "hetero"),
                                     (4, (0, 1), "dp")],
        }.items():
            record(key, lambda trc=trc: elastic_case(trc))

    # 8. axis_index_groups subgroup reduces: a SplitAR plan lowers its
    #    cross-subgroup reduce groups onto grouped collectives (the kind
    #    sweep above re-proves bit-exactness on both reduction paths)
    def grouped_case():
        from repro.core.comm_resolve import resolve
        from repro.runtime.backend import compile_plan

        src, dst = kind_cases(4)["SplitAR"]
        plan = resolve(src, dst, SHAPE)
        cp = compile_plan(plan, SHAPE, meshes[4])
        assert cp.stats.reduce_groups > 0, vars(cp.stats)
        assert cp.stats.grouped_reduces == cp.stats.reduce_groups, \
            vars(cp.stats)
        from repro.core.simulator import apply_plan, scatter
        st = scatter(value, src, rng=np.random.default_rng(5))
        sim = apply_plan(st, plan)
        out = cp(st.parts)
        for dev, arr in sim.parts.items():
            np.testing.assert_array_equal(out[dev], arr)
        return {"reduce_groups": cp.stats.reduce_groups,
                "grouped": cp.stats.grouped_reduces}
    if 4 in meshes:
        record("grouped:reduce/4", grouped_case)

    # 9. copy-stage lowering tiers: the full-mesh AG multicast is a
    #    *uniform gather stage* — one all_gather, zero permutes, zero
    #    switches — while a plan narrower than the mesh falls back to
    #    the general path, whose per-(src,dst) ppermute pairs fuse into
    #    batched permutes (fewer launches than pairs, same bits; the
    #    differential sweep above re-proves exactness)
    def fusion_case():
        from repro.core.comm_resolve import resolve
        from repro.runtime.backend import compile_plan

        src, dst = kind_cases(big)["AG"]
        plan = resolve(src, dst, SHAPE)
        cp = compile_plan(plan, SHAPE, meshes[big])
        uni = cp.stats
        assert uni.uniform_copy_stages == uni.stages > 0, vars(uni)
        assert uni.ppermute_calls == 0, vars(uni)
        out = cp({d: v for d, v in
                  zip(range(big), np.split(value, big, axis=0))})
        for dev in range(big):  # after AG every device holds the value
            np.testing.assert_array_equal(out[dev], value)

        small = big // 2        # narrower than the mesh -> general path
        src, dst = kind_cases(small)["AG"]
        plan = resolve(src, dst, SHAPE)
        cp = compile_plan(plan, SHAPE, meshes[big])
        stats = cp.stats
        assert stats.uniform_copy_stages == 0, vars(stats)
        assert stats.copy_pairs > 0 and \
            stats.ppermute_calls < stats.copy_pairs, vars(stats)
        out = cp({d: v for d, v in
                  zip(range(small), np.split(value, small, axis=0))})
        for dev in range(small):
            np.testing.assert_array_equal(out[dev], value)
        return {"copy_pairs": stats.copy_pairs,
                "ppermute_calls": stats.ppermute_calls,
                "uniform_copy_stages": uni.uniform_copy_stages}
    record(f"fusion:stats/{big}", fusion_case)

    report["ok"] = all(c["ok"] for c in report["cases"].values())
    return report


def main() -> int:
    report = run_all(max_devices=_ARGS.devices)
    for key, c in sorted(report["cases"].items()):
        status = "ok" if c["ok"] else f"FAIL: {c.get('error')}"
        print(f"  {key:24s} {status}")
    print("RUNTIME_SELFTEST_JSON " + json.dumps(report))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
