"""Async MPMD execution: one XLA program per (virtual) pipeline stage.

``runtime.program.LoweredGraph`` lowers the whole graph — every stage,
every microbatch — into ONE scanned ``shard_map`` program; XLA's
dependence order realizes the pipeline, but every P2P send and every
grad all-reduce serializes inside that single dispatch.  This module is
the MPMD alternative (JaxPP direction): the graph's ops are bucketed by
``(virtual stage, phase)`` (``core.schedule.assign_stages`` — exactly
the buckets the SimulatorExecutor's timetable ticks execute), each
bucket compiles to its OWN ``shard_map`` program over the same 1-D
mesh, and the dispatch loop walks the explicit 1F1B / GPipe /
interleaved timetable issuing programs as their inputs become ready:

* **per-stage programs** — a bucket's compute ops lower through the
  SAME specialization-class emission as the scanned program
  (``runtime.program.emit_segment`` over a ``partition_graph`` of the
  bucket's ops), so per-class branches, dtype chains and pad/unpad
  slicing are bitwise identical to the single-program path,
* **double-buffered P2P** — stage-boundary comm ops (activation sends,
  cotangent sends, interleaved wrap-arounds) are split OUT of the
  receiving stage's program into :class:`CommChannel`\\ s issued eagerly
  the moment the producing tick's program is dispatched; jax's async
  dispatch then moves microbatch ``j+1``'s activations while microbatch
  ``j``'s tick computes, through a bounded 2-slot in-flight window
  (issuing a third send blocks on the oldest — real back-pressure),
* **grad-reduce overlapped into backward** — a backward tick's trailing
  grad-reduce comm (output unconsumed inside the bucket) is hoisted out
  of the stage program and issued immediately after the tick, so the
  reduce rendezvous overlaps the NEXT tick's compute instead of
  serializing the epilogue.

One platform constraint shapes the dispatch loop: XLA's host-CPU
collectives rendezvous through a shared thread pool, and two
concurrently executing collective-bearing computations can park their
threads at different rendezvous until neither can proceed.  The loop
therefore keeps at most ONE collective-bearing computation in flight
(``AsyncLoweredGraph._coll_window``) — compute-only stage programs and
host-side dispatch still overlap it, and since the window only ever
adds blocking, numerics are unchanged.

Splitting a comm op out of its stage program never changes numerics:
the channel program traces the identical ``PlanLowering.apply`` on the
identical stacked buffers, and the scanned program's batched uniform-
reduce flush is documented bit-identical to one-at-a-time emission —
which is why ``AsyncExecutor`` is differentially bit-exact against BOTH
existing executors (``async:*`` selftest cases).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.graph import Graph
from repro.core.lowered_ir import CommSlot, partition_graph
from repro.core.schedule import (SCHEDULES, PipelineSchedule, ScheduleError,
                                 assign_stages, infer_virtual_stages)
from repro.core.simulator import ShardedTensor
from repro.core.specialize import construct_pipelines, resolve_comm_ops
from repro.core.symbolic import bind_shape
from repro.core.topology import Topology
from repro.kernels.policy import select_attention_impl_per_class

from .lowering import (DeviceOrder, LoweringStats, PlanLowering, maybe_x64,
                       pack_shards, pad_shape)
from .program import emit_segment, fetch_rows, segment_liveness, unpack_rows


def _phase_of(op) -> str:
    return "bwd" if op.attrs.get("phase") == "bwd" else "fwd"


@dataclass
class StageProgram:
    """One (virtual stage, phase) bucket compiled to its own jitted
    ``shard_map`` program: ``fn(*in_buffers) -> out_buffers``, all
    stacked ``(mesh, *pad)`` arrays."""

    stage: int
    phase: str
    ops: list
    in_names: list[str]
    out_names: list[str]
    fn: object
    # True when the bucket's partitioned IR kept inline comm ops (e.g.
    # a tp all-reduce inside the stage): such programs enter the global
    # one-in-flight collective window in ``_execute``
    has_collectives: bool = True


@dataclass
class CommChannel:
    """A comm op split out of its stage program and issued eagerly at
    the tick that produces its input.

    ``kind`` is ``"p2p"`` (activation / cotangent / wrap-around send)
    or ``"reduce"`` (grad-reduce and other reducing plans).  ``slots``
    bounds the in-flight window: issuing past it blocks on the oldest
    outstanding transfer first (the double-buffer discipline)."""

    op: object
    kind: str
    trigger: tuple[int, str]
    in_name: str
    out_name: str
    fn: object
    slots: int = 2
    inflight: deque = field(default_factory=deque)


class AsyncLoweredGraph:
    """A deduced graph + strategy compiled to one program per (virtual
    stage, phase) bucket plus split-out comm channels, dispatched
    asynchronously over an explicit timetable.

    The same graph/strategy/shape machinery as
    :class:`~repro.runtime.program.LoweredGraph`, but instead of one
    scanned whole-mesh program the lowering re-partitions each bucket's
    ops separately (``partition_graph(..., ops=bucket)`` — a whole-graph
    segment may span a stage/phase boundary with no comm op on it, e.g.
    the last stage's loss where fwd flows straight into bwd) and the
    explicit timetable that is only advisory for the scanned program
    becomes the actual dispatch order here."""

    def __init__(self, graph: Graph, strategy: int = 0, *,
                 shape_env: dict[str, int] | None = None, mesh=None,
                 topology: Topology | None = None,
                 reduction: str = "exact", fetches=None,
                 virtual_stages_per_device: int | None = None):
        self.graph = graph
        self.k = strategy
        self.reduction = reduction
        self.serialize = False      # block after every issue (bench knob)
        env = shape_env or {}
        self.shapes = {name: bind_shape(t.shape, env)
                       for name, t in graph.tensors.items()}
        resolved = resolve_comm_ops(graph, strategy, topology, shape_env)
        self._plans = {id(rc.op): rc.plan for rc in resolved}
        self.pipelines = construct_pipelines(graph, strategy,
                                             resolved_comms=resolved)
        self.n_stages = max((p.n_stages for p in self.pipelines),
                            default=1)
        inferred = infer_virtual_stages(graph, strategy, self.pipelines)
        self.v = inferred if virtual_stages_per_device is None \
            else virtual_stages_per_device
        self.n_virtual = self.n_stages * self.v
        # raises ScheduleError when the graph wraps more than v allows
        stage_of = assign_stages(graph, strategy, self.pipelines,
                                 virtual_stages_per_device=self.v)
        self._pack_bufs: dict[str, np.ndarray] = {}
        # the global collective window (see _execute): outputs of the
        # most recently issued collective-bearing computation
        self._inflight_coll: deque = deque()

        devs: set[int] = set()
        for t in graph.tensors.values():
            if t.annots:
                devs |= set(t.annots[strategy].devices)
        for plan in self._plans.values():
            for annot in plan.annots:
                devs |= set(annot.devices)
        self.order = DeviceOrder(tuple(sorted(devs)))

        if mesh is None:
            from repro.launch.mesh import make_runtime_mesh
            mesh = make_runtime_mesh(len(self.order))
        self.mesh = mesh
        self.n_mesh = int(mesh.devices.size)
        if self.n_mesh < len(self.order):
            raise ValueError(
                f"graph spans {len(self.order)} logical devices but mesh "
                f"has only {self.n_mesh}; force more host devices (e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{len(self.order)})")
        self.axis = mesh.axis_names[0]

        self.leaves = [o.outputs[0] for o in graph.ops
                       if o.kind in ("placeholder", "parameter")]
        self._per_mb = {t.name for t in self.leaves
                        if t.producer is not None
                        and t.producer.kind == "placeholder"}
        self.fetches = list(fetches or [t.name for t in graph.sinks()])
        for f in self.fetches:
            if f not in graph.tensors:
                raise ValueError(f"unknown fetch tensor {f!r}")

        self._consumers: dict[str, set[int]] = {}
        for op in graph.ops:
            for t in op.inputs:
                self._consumers.setdefault(t.name, set()).add(id(op))

        k, shapes = strategy, self.shapes

        def impl_of(op, dev):
            if op.kind != "attention":
                return ""
            qs = shapes[op.inputs[0].name]
            ks = shapes[op.inputs[1].name]
            return select_attention_impl_per_class(
                tuple(op.inputs[0].annots[k].device_shape(dev, qs)),
                tuple(op.inputs[1].annots[k].device_shape(dev, ks)))

        # bucket the schedulable ops exactly like the simulator's ticks
        buckets: dict[tuple[int, str], list] = {}
        for op in graph.ops:
            if op.kind in ("placeholder", "parameter"):
                continue
            buckets.setdefault(
                (stage_of[id(op)], _phase_of(op)), []).append(op)

        self.stats = LoweringStats()
        self.programs: dict[tuple[int, str], StageProgram] = {}
        self.channels: list[CommChannel] = []
        # (stage, phase) -> channels issued right after that tick
        self.triggers: dict[tuple[int, str], list[CommChannel]] = {}

        for key in sorted(buckets):
            ops = buckets[key]
            # classify each comm op: split OUT of the stage program when
            # its input crosses a bucket boundary (boundary P2P) or its
            # output escapes the bucket untouched (trailing grad-reduce
            # / wrap-around send); walk in reverse so a comm op's
            # in-bucket consumers are already classified
            status: dict[int, str] = {}
            for op in reversed(ops):
                if op.kind != "comm":
                    status[id(op)] = "inline"
                    continue
                producer = graph.tensors[op.inputs[0].name].producer
                leaf = producer is None or \
                    producer.kind in ("placeholder", "parameter")
                pb = key if leaf else \
                    (stage_of[id(producer)], _phase_of(producer))
                if pb != key:
                    status[id(op)] = "split"
                    continue
                out = op.outputs[0].name
                consumed_inline = any(
                    status.get(cid) == "inline"
                    for cid in self._consumers.get(out, ()))
                status[id(op)] = "inline" if consumed_inline else "split"
            inline_ops = [op for op in ops if status[id(op)] == "inline"]
            for op in ops:
                if status[id(op)] != "split":
                    continue
                producer = graph.tensors[op.inputs[0].name].producer
                leaf = producer is None or \
                    producer.kind in ("placeholder", "parameter")
                trigger = key if leaf else \
                    (stage_of[id(producer)], _phase_of(producer))
                ch = self._compile_channel(op, trigger)
                self.channels.append(ch)
                self.triggers.setdefault(trigger, []).append(ch)
            prog = self._compile_bucket(key, inline_ops, impl_of)
            if prog is not None:
                self.programs[key] = prog
        self._counted_ops = sum(len(p.ops)
                                for p in self.programs.values()) \
            + len(self.channels)

    # -- compilation -------------------------------------------------------

    def _plan_lowering(self, op) -> PlanLowering:
        pl = PlanLowering(self._plans[id(op)],
                          self.shapes[op.inputs[0].name], self.order,
                          self.axis, self.n_mesh,
                          reduction=self.reduction)
        self.stats.merge(pl.stats)
        return pl

    def _compile_channel(self, op, trigger) -> CommChannel:
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        pl = self._plan_lowering(op)
        axis = self.axis

        def body(block):
            x = block[0]
            i = jax.lax.axis_index(axis)
            return pl.apply(x, i, x.dtype)[None]

        spec = P(axis, *([None] * len(self.shapes[op.inputs[0].name])))
        jitted = jax.jit(shard_map(body, mesh=self.mesh, in_specs=spec,
                                   out_specs=spec, check_rep=False))
        fn = maybe_x64(jitted,
                       pl.needs_x64 and self.reduction == "exact")
        return CommChannel(
            op, "reduce" if pl.has_reduce else "p2p", trigger,
            op.inputs[0].name, op.outputs[0].name, fn)

    def _compile_bucket(self, key, inline_ops, impl_of
                        ) -> StageProgram | None:
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        if not inline_ops:
            return None
        graph, k, shapes = self.graph, self.k, self.shapes
        order, n_mesh, axis = self.order, self.n_mesh, self.axis
        inline_ids = {id(op) for op in inline_ops}
        produced = {op.outputs[0].name for op in inline_ops}
        in_names: list[str] = []
        for op in inline_ops:
            for t in op.inputs:
                if t.name not in produced and t.name not in in_names:
                    in_names.append(t.name)
        fetch_set = set(self.fetches)
        out_names = [
            op.outputs[0].name for op in inline_ops
            if op.outputs[0].name in fetch_set
            or (self._consumers.get(op.outputs[0].name, set())
                - inline_ids)]
        if not out_names:
            return None             # dead bucket: nothing escapes

        ir = partition_graph(graph, k, shapes=shapes, impl_of=impl_of,
                             devices=order.devices, ops=inline_ops)
        seg_live = segment_liveness(graph, ir.segments, out_names)
        extra_idle = n_mesh > len(order)
        for seg in ir.segments:
            if not seg_live[id(seg)][1]:
                continue
            self.stats.compute_segments += 1
            if seg.is_homogeneous() and not extra_idle:
                self.stats.straightline_segments += 1
            else:
                idle = 1 if (seg.idle_devices or extra_idle) else 0
                self.stats.switch_branches_emitted += \
                    seg.n_classes + idle
            for cls in seg.classes:
                for op, spec in zip(seg.ops, cls.specs):
                    if op.kind == "attention" and spec is not None:
                        if spec.impl == "pallas":
                            self.stats.pallas_dispatches += 1
                        else:
                            self.stats.ref_dispatches += 1
        lowerings: dict[int, PlanLowering] = {}
        needs_x64 = False
        for entry in ir.entries:
            if isinstance(entry, CommSlot):
                pl = self._plan_lowering(entry.op)
                lowerings[id(entry.op)] = pl
                needs_x64 |= pl.needs_x64

        def body(*blocks):
            i = jax.lax.axis_index(axis)
            tenv = {n: b[0] for n, b in zip(in_names, blocks)}
            for entry in ir.entries:
                if isinstance(entry, CommSlot):
                    op = entry.op
                    x = tenv[op.inputs[0].name]
                    tenv[op.outputs[0].name] = \
                        lowerings[id(op)].apply(x, i, x.dtype)
                else:
                    emit_segment(entry, tenv, i, seg_live=seg_live,
                                 graph=graph, k=k, shapes=shapes,
                                 order=order, n_mesh=n_mesh)
            return tuple(tenv[n][None] for n in out_names)

        in_specs = tuple(P(axis, *([None] * len(shapes[n])))
                         for n in in_names)
        out_specs = tuple(P(axis, *([None] * len(shapes[n])))
                          for n in out_names)
        jitted = jax.jit(shard_map(body, mesh=self.mesh,
                                   in_specs=in_specs,
                                   out_specs=out_specs,
                                   check_rep=False))
        fn = maybe_x64(jitted, needs_x64 and self.reduction == "exact")
        return StageProgram(key[0], key[1], list(inline_ops), in_names,
                            out_names, fn,
                            has_collectives=bool(lowerings))

    # -- introspection -----------------------------------------------------

    def describe(self) -> str:
        lines = [f"{len(self.programs)} stage program(s), "
                 f"{len(self.channels)} comm channel(s) over "
                 f"{self.n_virtual} virtual stage(s) "
                 f"(S={self.n_stages}, v={self.v})"]
        for key in sorted(self.programs):
            p = self.programs[key]
            lines.append(
                f"  [{p.phase} vstage {p.stage}] {len(p.ops)} op(s): "
                f"{len(p.in_names)} in -> {len(p.out_names)} out")
        for ch in self.channels:
            lines.append(
                f"  channel {ch.kind} {ch.in_name} -> {ch.out_name} "
                f"(after {ch.trigger[1]} vstage {ch.trigger[0]})")
        return "\n".join(lines)

    # -- pack / execute / fetch --------------------------------------------

    def _pack(self, st: ShardedTensor, annot, shape,
              buf_key: str | None = None) -> np.ndarray:
        out = self._pack_bufs.get(buf_key) if buf_key else None
        stacked = pack_shards(st.parts, annot, shape, self.n_mesh,
                              self.order, out=out)
        if buf_key:
            self._pack_bufs[buf_key] = stacked
        return stacked

    def _put_all(self, blocks: list[np.ndarray]):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        axis = self.axis
        shardings = [
            NamedSharding(self.mesh, P(axis, *([None] * (b.ndim - 1))))
            for b in blocks]
        return jax.device_put(blocks, shardings)

    def _make_envs(self, states) -> list[dict]:
        m = len(states)
        blocks: list[np.ndarray] = []
        slots: list[tuple[int | None, str]] = []
        for t in self.leaves:
            annot = t.annots[self.k]
            shape = self.shapes[t.name]
            if t.name in self._per_mb and m > 1:
                for j, st in enumerate(states):
                    if t.name not in st:
                        raise ValueError(
                            f"missing leaf tensor {t.name!r}")
                    blocks.append(self._pack(st[t.name], annot, shape,
                                             buf_key=f"{t.name}#{j}"))
                    slots.append((j, t.name))
            else:
                if t.name not in states[0]:
                    raise ValueError(f"missing leaf tensor {t.name!r}")
                blocks.append(self._pack(states[0][t.name], annot,
                                         shape, buf_key=t.name))
                slots.append((None, t.name))
        puts = self._put_all(blocks)
        envs: list[dict] = [{} for _ in range(m)]
        for (j, name), arr in zip(slots, puts):
            if j is None:
                for env in envs:
                    env[name] = arr
            else:
                envs[j][name] = arr
        return envs

    def _coll_window(self) -> None:
        """Admit one more collective-bearing computation.

        XLA's host-CPU collectives rendezvous through a shared thread
        pool: two computations whose collectives span overlapping
        device sets can execute concurrently, each parking threads at
        its own rendezvous until neither can finish (observed as a
        live process stuck at ``AllReduce``/``AllGather`` rendezvous
        forever).  The cure that preserves MPMD overlap: keep at most
        ONE collective-bearing computation in flight — block on the
        previous one's outputs before issuing the next.  Compute-only
        stage programs and host-side dispatch still overlap freely,
        and numerics are untouched (this only ever adds blocking)."""
        while self._inflight_coll:
            self._inflight_coll.popleft().block_until_ready()

    def _execute(self, ticks, envs) -> None:
        """Walk ``(stage, microbatch, phase)`` ticks in order: issue the
        tick's stage program, then eagerly issue every channel whose
        input that tick produced.  Nothing blocks except the channel
        back-pressure window, the one-in-flight collective window
        (``_coll_window``) and the final fetch — jax's async dispatch
        is what overlaps a channel's collective with the next tick's
        compute."""
        for ch in self.channels:
            ch.inflight.clear()
        self._inflight_coll.clear()
        ran = [0] * len(envs)
        for stage, mb, phase in ticks:
            env = envs[mb]
            key = (stage, phase)
            prog = self.programs.get(key)
            if prog is not None:
                try:
                    ins = [env[n] for n in prog.in_names]
                except KeyError as e:
                    raise ScheduleError(
                        f"stage {stage} ({phase}) ran before its input "
                        f"{e} was produced (invalid schedule)") from None
                if prog.has_collectives:
                    self._coll_window()
                outs = prog.fn(*ins)
                if self.serialize:
                    for y in outs:
                        y.block_until_ready()
                elif prog.has_collectives:
                    self._inflight_coll.extend(outs)
                env.update(zip(prog.out_names, outs))
                ran[mb] += len(prog.ops)
            for ch in self.triggers.get(key, ()):
                x = env.get(ch.in_name)
                if x is None:
                    raise ScheduleError(
                        f"stage {stage} ({phase}) ran before its input "
                        f"'{ch.in_name}' was produced (invalid "
                        f"schedule)")
                if len(ch.inflight) >= ch.slots:
                    ch.inflight.popleft().block_until_ready()
                self._coll_window()
                y = ch.fn(x)
                if self.serialize:
                    y.block_until_ready()
                else:
                    ch.inflight.append(y)
                    self._inflight_coll.append(y)
                env[ch.out_name] = y
                ran[mb] += 1
        if any(r != self._counted_ops for r in ran):
            raise ScheduleError(
                f"schedule executed {ran} of {self._counted_ops} ops "
                f"per microbatch")

    def _fetch(self, envs) -> list[dict[str, ShardedTensor]]:
        results = []
        for env in envs:
            outs = []
            for f in self.fetches:
                if f not in env:
                    raise ScheduleError(
                        f"fetch {f!r} was never produced (invalid "
                        f"schedule)")
                outs.append(env[f])
            rows = fetch_rows(outs, self.n_mesh)
            results.append({
                f: unpack_rows(self.graph, self.k, self.shapes,
                               self.order, f, r)
                for f, r in zip(self.fetches, rows)})
        return results

    def run(self, state: dict[str, ShardedTensor]
            ) -> dict[str, ShardedTensor]:
        """Unpipelined execution (one microbatch): dispatch the buckets
        in the canonical fwd 0..nv-1 then bwd nv-1..0 order."""
        envs = self._make_envs([state])
        nv = self.n_virtual
        order = [(s, 0, "fwd") for s in range(nv)] \
            + [(s, 0, "bwd") for s in reversed(range(nv))]
        self._execute(order, envs)
        return self._fetch(envs)[0]

    def run_schedule(self, schedule: PipelineSchedule, states
                     ) -> list[dict[str, ShardedTensor]]:
        """Dispatch an explicit timetable over per-microbatch states."""
        if len(states) != schedule.num_microbatches:
            raise ScheduleError(
                f"{len(states)} microbatch states for a "
                f"{schedule.num_microbatches}-microbatch schedule")
        envs = self._make_envs(list(states))
        self._execute([(t.stage, t.microbatch, t.phase)
                       for t in schedule.ticks], envs)
        return self._fetch(envs)


class AsyncExecutor:
    """MPMD per-stage dispatch on real devices (the third executor).

    Same contract as ``SimulatorExecutor`` / ``JaxExecutor`` —
    ``{name: ShardedTensor}`` in, per-microbatch fetches out, bit-exact
    against both — but the explicit timetable is the actual dispatch
    order: per-stage programs launch as their inputs arrive, boundary
    P2P moves through double-buffered channels, and grad-reduces issue
    eagerly inside the backward wave.  ``serialize=True`` blocks after
    every issue (the sync baseline the overlap benchmark measures
    against)."""

    name = "async"
    supported_schedules = SCHEDULES

    def __init__(self, mesh=None, *, reduction: str = "exact",
                 serialize: bool = False):
        import weakref
        self.mesh = mesh
        self.reduction = reduction
        self.serialize = serialize
        self._cache: "weakref.WeakKeyDictionary" = \
            weakref.WeakKeyDictionary()

    def lowered(self, compiled, fetches=None,
                virtual_stages_per_device: int | None = None
                ) -> AsyncLoweredGraph:
        """The (cached) per-stage lowering for this plan + fetch list."""
        per_plan = self._cache.get(compiled)
        if per_plan is None:
            per_plan = self._cache[compiled] = {}
        v = compiled.virtual_stages_per_device \
            if virtual_stages_per_device is None \
            else virtual_stages_per_device
        key = (tuple(fetches) if fetches else None, v)
        lw = per_plan.get(key)
        if lw is None:
            lw = AsyncLoweredGraph(
                compiled.graph, compiled.strategy_index,
                shape_env=compiled.shape_env, mesh=self.mesh,
                topology=compiled.topology, reduction=self.reduction,
                fetches=list(fetches) if fetches else None,
                virtual_stages_per_device=v)
            per_plan[key] = lw
        lw.serialize = self.serialize
        return lw

    def run(self, compiled, state, fetches=None
            ) -> dict[str, ShardedTensor]:
        return self.lowered(compiled, fetches).run(state)

    def run_schedule(self, compiled, schedule: PipelineSchedule, states,
                     fetches=None) -> list[dict[str, ShardedTensor]]:
        if schedule.kind not in self.supported_schedules:
            raise ScheduleError(
                f"executor {self.name!r} does not support schedule kind "
                f"{schedule.kind!r}; supported kinds are "
                f"{', '.join(repr(s) for s in self.supported_schedules)}")
        if len(states) != schedule.num_microbatches:
            raise ScheduleError(
                f"{len(states)} microbatch states for a "
                f"{schedule.num_microbatches}-microbatch schedule")
        if schedule.n_stages != compiled.n_stages:
            raise ScheduleError(
                f"schedule has {schedule.n_stages} stage(s) but the plan "
                f"has {compiled.n_stages}")
        lw = self.lowered(compiled, fetches,
                          virtual_stages_per_device=schedule.
                          virtual_per_stage)
        return lw.run_schedule(schedule, list(states))
