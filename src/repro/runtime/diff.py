"""Differential testing layer: real-device execution vs the simulator.

Every plan the runtime executes can be checked **bit exactly** against
``simulator.apply_plan`` — the simulator is the executable specification of
the paper's §4 semantics, the shard_map backend is the implementation under
test.  ``reduction="exact"`` reproduces the simulator's float64-ordered
accumulation for arbitrary data; the ``"fast"`` psum path is checked with
integer-valued shards (order-insensitive sums), via
:func:`integer_decompose`.
"""

from __future__ import annotations

import numpy as np

from repro.core.annotations import HSPMD
from repro.core.comm_resolve import resolve
from repro.core.plan import CommPlan
from repro.core.simulator import ShardedTensor, apply_plan, gather, scatter
from repro.core.topology import Topology

from .backend import execute_plan


def integer_decompose(value: np.ndarray, k: int,
                      rng: np.random.Generator) -> list[np.ndarray]:
    """Summand decomposition over small integers: float32 sums of these are
    exact in ANY association order, making psum bit-comparable."""
    if k == 1:
        return [value]
    pieces = [rng.integers(-8, 9, size=value.shape).astype(value.dtype)
              for _ in range(k - 1)]
    pieces.append(value - sum(pieces))
    return pieces


def differential_check(value: np.ndarray, src: HSPMD, dst: HSPMD,
                       mesh=None, *, plan: CommPlan | None = None,
                       topology: Topology | None = None,
                       reduction: str = "exact",
                       rng: np.random.Generator | None = None,
                       decompose=None) -> CommPlan:
    """Resolve (src, dst), execute on the simulator AND on real devices,
    assert per-device bit-exact agreement.  Returns the plan (so callers
    can assert which operator kinds were exercised)."""
    shape = tuple(value.shape)
    if plan is None:
        plan = resolve(src, dst, shape, topology)
    st = scatter(value, src, rng=rng, decompose=decompose)
    sim = apply_plan(st, plan)
    real = execute_plan(plan, st.parts, shape, mesh, reduction=reduction)
    assert set(real) == set(sim.parts), (sorted(real), sorted(sim.parts))
    for dev, arr in sim.parts.items():
        np.testing.assert_array_equal(
            real[dev], arr,
            err_msg=f"dev {dev} differs from simulator "
                    f"(plan {plan.kind}, reduction={reduction})")
    return plan


def roundtrip_check(value: np.ndarray, src: HSPMD, dst: HSPMD,
                    mesh=None, *, topology: Topology | None = None,
                    reduction: str = "exact") -> None:
    """src -> dst -> src on real devices recovers the tensor: final shards
    equal the initial scatter exactly, and the gathered global value is
    unchanged."""
    shape = tuple(value.shape)
    there = resolve(src, dst, shape, topology)
    back = resolve(dst, src, shape, topology)
    st = scatter(value, src)
    mid = execute_plan(there, st.parts, shape, mesh, reduction=reduction)
    out = execute_plan(back, mid, shape, mesh, reduction=reduction)
    for dev, arr in st.parts.items():
        np.testing.assert_array_equal(out[dev], arr,
                                      err_msg=f"dev {dev} round-trip drift")
    recon = gather(ShardedTensor(shape, src, out))
    np.testing.assert_allclose(recon, value, atol=1e-5)
