"""Whole-graph execution on real devices: compute + comm ExecItems.

``runtime.lowering`` executes a single CommPlan; this module lowers an
entire deduced :class:`~repro.core.graph.Graph` — every compute op AND
every resolved CommOp — into ONE ``jax.shard_map`` program over a 1-D
device mesh, so a progressively-specialized pipeline stage runs
end-to-end on real devices (paper §5.3-5.4):

* each tensor lives as a stacked ``(mesh, *padded_local)`` buffer whose
  row ``order.pos(dev)`` holds device ``dev``'s local shard at the
  origin (heterogeneous ``hsplits`` boxes are zero-padded to the
  per-tensor elementwise-max box shape),
* a compute op becomes a ``jax.lax.switch`` over ``axis_index`` whose
  branches are the *per-device* local computations — each branch slices
  its device's exact local input shapes, applies the shared local
  semantics (``core.op_semantics.local_apply``), and re-pads.  A device
  outside the op's output annotation gets a zero branch: non-local
  operator removal, executed literally,
* a CommOp applies its resolved plan's stages via
  :class:`~repro.runtime.lowering.PlanLowering` (fused batched permutes,
  exact or fast reductions) on the same buffers.

The per-device programs are exactly the ExecItem lists progressive
specialization produces (``core.specialize.specialize``); the
SimulatorExecutor interprets the same items with numpy, which is what the
differential tests compare against.

Joint fwd+bwd TRAINING graphs (``Program.compile_train``) lower through
the very same path: backward ops are ordinary graph ops (autodiff VJP
kernels share ``local_apply`` with the simulator), activation-grad and
grad-reduce CommOps are resolved plans like any other, and the scanned
microbatch axis carries the per-microbatch gradient summands — so one
shard_map program realizes the whole fwd → bwd → grad-reduce step that
the SimulatorExecutor executes as explicit fwd/bwd timetable ticks
(bit-exact parity checked by the ``api:train/*`` selftest cases).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.core.op_semantics import local_apply, result_dtype
from repro.core.simulator import ShardedTensor
from repro.core.specialize import resolve_comm_ops
from repro.core.symbolic import bind_shape
from repro.core.topology import Topology
from repro.kernels.policy import select_attention_impl

from .lowering import (DeviceOrder, LoweringStats, PlanLowering, maybe_x64,
                       pack_shards, pad_shape)


class LoweredGraph:
    """A deduced graph + strategy compiled to one shard_map program,
    reusable over fresh shard values without retracing.

    With ``num_microbatches=m > 1`` the SAME program additionally scans
    over a leading microbatch axis: placeholder buffers carry all ``m``
    microbatch shards stacked at axis 1, a ``jax.lax.scan`` runs the
    per-device body (unchanged ``lax.switch`` branches + comm lowerings)
    once per microbatch, and every fetch comes back per-microbatch — the
    pipeline schedule's work, expressed as one XLA program whose
    dependence order realizes the same 1F1B/GPipe overlap.  The graph
    passed in must then be the MICRO graph (shapes already scaled;
    ``Program.compile_micro``).

    Interleaved virtual stages (Megatron's ``v`` chunks per device;
    ``schedule.infer_virtual_stages``) need no special lowering: a
    device holding ``v`` chunks simply contributes the ops of ALL its
    chunks to its switch branch, and the wrap-around CommOps route
    activations around the device ring ``v`` times inside the same
    scanned body.  ``n_virtual_stages`` surfaces the deduced chunk
    structure (``n_stages * v``) for introspection — the explicit
    interleaved timetable remains the SimulatorExecutor's contract,
    checked bit-exactly against this program by the
    ``api:pipeline/interleaved*`` selftest cases."""

    def __init__(self, graph: Graph, strategy: int = 0, *,
                 shape_env: dict[str, int] | None = None, mesh=None,
                 topology: Topology | None = None,
                 reduction: str = "exact", fetches=None,
                 num_microbatches: int = 1):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        self.graph = graph
        self.k = strategy
        if num_microbatches < 1:
            raise ValueError(
                f"num_microbatches must be >= 1 (got {num_microbatches})")
        self.num_microbatches = num_microbatches
        env = shape_env or {}
        self.shapes = {name: bind_shape(t.shape, env)
                       for name, t in graph.tensors.items()}
        resolved = resolve_comm_ops(graph, strategy, topology, shape_env)
        self._plans = {id(rc.op): rc.plan for rc in resolved}
        # kept for the lazy pipeline/chunk introspection properties
        self._resolved_comms = resolved
        self._pipelines: "list | None" = None

        devs: set[int] = set()
        for t in graph.tensors.values():
            if t.annots:
                devs |= set(t.annots[strategy].devices)
        for plan in self._plans.values():
            for annot in plan.annots:
                devs |= set(annot.devices)
        self.order = DeviceOrder(tuple(sorted(devs)))

        if mesh is None:
            from repro.launch.mesh import make_runtime_mesh
            mesh = make_runtime_mesh(len(self.order))
        self.mesh = mesh
        self.n_mesh = int(mesh.devices.size)
        if self.n_mesh < len(self.order):
            raise ValueError(
                f"graph spans {len(self.order)} logical devices but mesh "
                f"has only {self.n_mesh}; force more host devices (e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{len(self.order)})")
        axis = mesh.axis_names[0]

        self.leaves = [o.outputs[0] for o in graph.ops
                       if o.kind in ("placeholder", "parameter")]
        self.fetches = list(fetches or [t.name for t in graph.sinks()])
        for f in self.fetches:
            if f not in graph.tensors:
                raise ValueError(f"unknown fetch tensor {f!r}")

        self.stats = LoweringStats()
        lowerings: dict[int, PlanLowering] = {}
        has_reduce = False
        for oid, plan in self._plans.items():
            shape = self.shapes[plan_input_name(graph, oid)]
            pl = PlanLowering(plan, shape, self.order, axis, self.n_mesh,
                              reduction=reduction)
            lowerings[oid] = pl
            self.stats.merge(pl.stats)
            has_reduce |= pl.has_reduce

        # Kernel dispatch is decided STATICALLY, per (op, device), from the
        # device-LOCAL shard shapes — a TP-split head dim can make a shard
        # kernel-eligible (or not) independent of the global shape.  The
        # jitted body is traced lazily, so the tally lives here, not in a
        # trace-time hook.
        self._attn_impl: dict[tuple[int, int], str] = {}
        for op in graph.ops:
            if op.kind != "attention":
                continue
            annot = op.outputs[0].annots[strategy]
            qa, ka = op.inputs[0].annots[strategy], op.inputs[1].annots[strategy]
            qs = self.shapes[op.inputs[0].name]
            ks = self.shapes[op.inputs[1].name]
            for dev in annot.devices:
                impl = select_attention_impl(
                    tuple(qa.device_shape(dev, qs)),
                    tuple(ka.device_shape(dev, ks)))
                self._attn_impl[(id(op), dev)] = impl
                if impl == "pallas":
                    self.stats.pallas_dispatches += 1
                else:
                    self.stats.ref_dispatches += 1

        k, order, n_mesh, shapes = strategy, self.order, self.n_mesh, \
            self.shapes

        def emit_compute(op, ins, i):
            import jax.numpy as jnp
            out_t = op.outputs[0]
            annot = out_t.annots[k]
            out_shape = shapes[out_t.name]
            out_pad = pad_shape(annot, out_shape)
            # shared promotion rule, matching the SimulatorExecutor
            dtype = result_dtype(op.kind, [np.dtype(v.dtype) for v in ins])

            def branch_for(pos):
                if pos >= len(order) or \
                        order.devices[pos] not in annot.devices:
                    return lambda *vs: jnp.zeros(out_pad, dtype)
                dev = order.devices[pos]
                in_shapes = [t.annots[k].device_shape(dev, shapes[t.name])
                             for t in op.inputs]
                out_local = tuple(annot.device_shape(dev, out_shape))

                impl = self._attn_impl.get((id(op), dev), "ref")

                def f(*vs):
                    locs = [v[tuple(slice(0, s) for s in shp)]
                            for v, shp in zip(vs, in_shapes)]
                    if impl == "pallas":
                        from repro.kernels.ops import attention as attn_kernel
                        y = attn_kernel(*locs,
                                        causal=op.attrs.get("causal", True),
                                        use_kernel="pallas")
                    else:
                        y = local_apply(op.kind, jnp, locs, op.attrs,
                                        out_local)
                    buf = jnp.zeros(out_pad, dtype)
                    return buf.at[tuple(slice(0, s)
                                        for s in y.shape)].set(
                        y.astype(dtype))

                return f

            return jax.lax.switch(i, [branch_for(p) for p in range(n_mesh)],
                                  *ins)

        # placeholders carry a per-microbatch axis in microbatched mode;
        # parameters are microbatch-invariant and stay single-buffer
        self._per_mb = {t.name for t in self.leaves
                        if t.producer is not None
                        and t.producer.kind == "placeholder"}
        m = num_microbatches

        def eval_ops(tenv, i):
            for op in graph.ops:
                if op.kind in ("placeholder", "parameter"):
                    continue
                out_name = op.outputs[0].name
                if op.kind == "comm":
                    x = tenv[op.inputs[0].name]
                    tenv[out_name] = lowerings[id(op)].apply(x, i, x.dtype)
                else:
                    tenv[out_name] = emit_compute(
                        op, [tenv[t.name] for t in op.inputs], i)
            return tenv

        def body(*blocks):
            i = jax.lax.axis_index(axis)
            if m == 1:
                tenv = {t.name: b[0] for t, b in zip(self.leaves, blocks)}
                tenv = eval_ops(tenv, i)
                return tuple(tenv[f][None] for f in self.fetches)
            shared = {t.name: b[0] for t, b in zip(self.leaves, blocks)
                      if t.name not in self._per_mb}
            xs = {t.name: b[0] for t, b in zip(self.leaves, blocks)
                  if t.name in self._per_mb}          # (m, *pad) each

            def mb_body(carry, x_j):
                tenv = eval_ops({**shared, **x_j}, i)
                return carry, tuple(tenv[f] for f in self.fetches)

            _, ys = jax.lax.scan(mb_body, 0, xs, length=m)  # ys (m, *pad)
            return tuple(y[None] for y in ys)

        def leaf_rank(t):
            rank = len(shapes[t.name])
            return rank + 1 if m > 1 and t.name in self._per_mb else rank

        in_specs = tuple(P(axis, *([None] * leaf_rank(t)))
                         for t in self.leaves)
        out_rank = {f: len(shapes[f]) + (1 if m > 1 else 0)
                    for f in self.fetches}
        out_specs = tuple(P(axis, *([None] * out_rank[f]))
                          for f in self.fetches)
        jitted = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=False))
        self.fn = maybe_x64(jitted, has_reduce and reduction == "exact")

    # -- introspection (lazy: not on the lowering/execution path) ----------

    @property
    def pipelines(self):
        """Deduced pipeline structure (shares the lowering's comm
        resolution); computed on first access."""
        if self._pipelines is None:
            from repro.core.specialize import construct_pipelines
            self._pipelines = construct_pipelines(
                self.graph, self.k, resolved_comms=self._resolved_comms)
        return self._pipelines

    @property
    def n_stages(self) -> int:
        return max((p.n_stages for p in self.pipelines), default=1)

    @property
    def n_virtual_stages(self) -> int:
        """Physical stages * interleave chunks (Megatron's ``S * v``)."""
        from repro.core.schedule import infer_virtual_stages
        return self.n_stages * infer_virtual_stages(
            self.graph, self.k, self.pipelines)

    # -- pack / unpack -----------------------------------------------------

    def _pack(self, st: ShardedTensor, annot, shape) -> np.ndarray:
        return pack_shards(st.parts, annot, shape, self.n_mesh, self.order)

    def _put(self, stacked: np.ndarray):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        axis = self.mesh.axis_names[0]
        spec = P(axis, *([None] * (stacked.ndim - 1)))
        return jax.device_put(stacked, NamedSharding(self.mesh, spec))

    def _unpack(self, name: str, arr: np.ndarray) -> ShardedTensor:
        annot = self.graph.tensors[name].annots[self.k]
        shape = self.shapes[name]
        parts = {
            dev: arr[(self.order.pos(dev),)
                     + tuple(slice(0, s)
                             for s in annot.device_shape(dev, shape))
                     ].copy()
            for dev in annot.devices}
        return ShardedTensor(shape, annot, parts)

    def run(self, state: dict[str, ShardedTensor]
            ) -> dict[str, ShardedTensor]:
        """Execute once; ``state`` maps every leaf name (placeholder AND
        parameter) to its ShardedTensor under the strategy annotation."""
        if self.num_microbatches != 1:
            raise ValueError("microbatched program: use run_microbatches")
        blocks = []
        for t in self.leaves:
            if t.name not in state:
                raise ValueError(f"missing leaf tensor {t.name!r}")
            annot = t.annots[self.k]
            blocks.append(self._put(self._pack(
                state[t.name], annot, self.shapes[t.name])))
        outs = self.fn(*blocks)
        return {name: self._unpack(name, np.asarray(out))
                for name, out in zip(self.fetches, outs)}

    def run_microbatches(self, states: list[dict[str, ShardedTensor]]
                         ) -> list[dict[str, ShardedTensor]]:
        """Execute the scanned program over ``num_microbatches`` leaf
        states (microbatch ``j``'s placeholders in ``states[j]``;
        parameters read from ``states[0]``).  Returns per-microbatch
        fetches, bit-comparable to ``SimulatorExecutor.run_schedule``."""
        m = self.num_microbatches
        if m == 1:
            raise ValueError("unpipelined program: use run")
        if len(states) != m:
            raise ValueError(
                f"{len(states)} microbatch states for a {m}-microbatch "
                f"program")
        blocks = []
        for t in self.leaves:
            annot = t.annots[self.k]
            shape = self.shapes[t.name]
            if t.name in self._per_mb:
                for st in states:
                    if t.name not in st:
                        raise ValueError(
                            f"missing leaf tensor {t.name!r}")
                blocks.append(self._put(np.stack(
                    [self._pack(st[t.name], annot, shape)
                     for st in states], axis=1)))
            else:
                if t.name not in states[0]:
                    raise ValueError(f"missing leaf tensor {t.name!r}")
                blocks.append(self._put(self._pack(
                    states[0][t.name], annot, shape)))
        outs = self.fn(*blocks)
        results: list[dict[str, ShardedTensor]] = [{} for _ in range(m)]
        for name, out in zip(self.fetches, outs):
            arr = np.asarray(out)          # (n_mesh, m, *pad)
            for j in range(m):
                results[j][name] = self._unpack(name, arr[:, j])
        return results


def plan_input_name(graph: Graph, op_id: int) -> str:
    for op in graph.comm_ops:
        if id(op) == op_id:
            return op.inputs[0].name
    raise KeyError(op_id)


def lower_graph(graph: Graph, strategy: int = 0, *,
                shape_env: dict[str, int] | None = None, mesh=None,
                topology: Topology | None = None, reduction: str = "exact",
                fetches=None, num_microbatches: int = 1) -> LoweredGraph:
    """Compile a deduced graph for one strategy; see :class:`LoweredGraph`."""
    return LoweredGraph(graph, strategy, shape_env=shape_env, mesh=mesh,
                        topology=topology, reduction=reduction,
                        fetches=fetches, num_microbatches=num_microbatches)
