"""Whole-graph execution on real devices: compute + comm ExecItems.

``runtime.lowering`` executes a single CommPlan; this module lowers an
entire deduced :class:`~repro.core.graph.Graph` — every compute op AND
every resolved CommOp — into ONE ``jax.shard_map`` program over a 1-D
device mesh, so a progressively-specialized pipeline stage runs
end-to-end on real devices (paper §5.3-5.4):

* each tensor that crosses a communication boundary lives as a stacked
  ``(mesh, *padded_local)`` buffer whose row ``order.pos(dev)`` holds
  device ``dev``'s local shard at the origin (heterogeneous ``hsplits``
  boxes are zero-padded to the per-tensor elementwise-max box shape),
* compute ops are lowered through the **specialization-class IR**
  (``core.lowered_ir``): maximal runs of compute ops between comm ops
  form segments, and each segment emits ONE branch per *class* of
  devices sharing the identical local program — in the common
  homogeneous SPMD case (one class, every device) the whole segment is
  straight-line unpadded code with zero switches; heterogeneous or
  pipeline-staged segments get a small ``jax.lax.switch`` over classes
  (never over devices), with a zero branch only when some mesh position
  idles through the segment (non-local operator removal, executed
  literally),
* a CommOp applies its resolved plan's stages via
  :class:`~repro.runtime.lowering.PlanLowering` (fused batched permutes,
  exact or fast reductions) on the same buffers.

The per-device programs are exactly the ExecItem lists progressive
specialization produces (``core.specialize.specialize``) — the class
partition is their quotient, checked against them by
``core.lowered_ir.check_against_exec_items`` — and the
SimulatorExecutor interprets the same classes with vectorized numpy,
which is what the differential tests compare against.

Joint fwd+bwd TRAINING graphs (``Program.compile_train``) lower through
the very same path: backward ops are ordinary graph ops (autodiff VJP
kernels share ``local_apply`` with the simulator), activation-grad and
grad-reduce CommOps are resolved plans like any other, and the scanned
microbatch axis carries the per-microbatch gradient summands — so one
shard_map program realizes the whole fwd → bwd → grad-reduce step that
the SimulatorExecutor executes as explicit fwd/bwd timetable ticks
(bit-exact parity checked by the ``api:train/*`` selftest cases).
"""

from __future__ import annotations

import numpy as np

from repro.core.graph import Graph
from repro.core.lowered_ir import (CommSlot, Segment, partition_graph)
from repro.core.op_semantics import local_apply, result_dtype
from repro.core.simulator import ShardedTensor
from repro.core.specialize import resolve_comm_ops
from repro.core.symbolic import bind_shape
from repro.core.topology import Topology
from repro.kernels.policy import select_attention_impl_per_class

from .lowering import (DeviceOrder, LoweringStats, PlanLowering, maybe_x64,
                       pack_shards, pad_shape)


# ---------------------------------------------------------------------------
# shared emission helpers (whole-graph AND per-stage lowerings)
#
# LoweredGraph (one scanned program) and runtime.async_program (one
# program per virtual pipeline stage) trace the SAME per-class segment
# code through these functions, which is what keeps the two backends
# bitwise interchangeable: a segment's class branches, dtype chain and
# pad/unpad slicing are one definition, not two.
# ---------------------------------------------------------------------------

def segment_liveness(graph: Graph, segments, fetches
                     ) -> dict[int, tuple[list[str], list[str]]]:
    """``id(segment) -> (live_in, live_out)``: values produced AND
    consumed inside one segment stay unpadded inside its branches; only
    live-outs (consumed by ops outside the segment, or fetched)
    materialize as stacked ``(mesh, *pad)`` buffers."""
    consumers: dict[str, set[int]] = {}
    for op in graph.ops:
        for t in op.inputs:
            consumers.setdefault(t.name, set()).add(id(op))
    fetch_set = set(fetches)
    out: dict[int, tuple[list[str], list[str]]] = {}
    for seg in segments:
        seg_ids = {id(op) for op in seg.ops}
        produced: list[str] = [op.outputs[0].name for op in seg.ops]
        produced_set = set(produced)
        live_in: list[str] = []
        for op in seg.ops:
            for t in op.inputs:
                if t.name not in produced_set and t.name not in live_in:
                    live_in.append(t.name)
        live_out = [n for n in produced
                    if n in fetch_set
                    or (consumers.get(n, set()) - seg_ids)]
        out[id(seg)] = (live_in, live_out)
    return out


def run_segment_class(seg, cls, dtypes, live_in, live_out, out_pads, vs):
    """Trace one class's local program over the segment: slice live-ins
    to the class's exact local shapes once, keep every interior value
    unpadded, re-pad only the live-outs."""
    import jax.numpy as jnp

    local = dict(zip(live_in, vs))
    exact: dict[str, object] = {}
    for op, spec in zip(seg.ops, cls.specs):
        if spec is None:
            continue        # this class does not run the op
        ins = []
        for t, shp in zip(op.inputs, spec.in_shapes):
            v = exact.get(t.name)
            if v is None:
                v = local[t.name]
                if tuple(v.shape) != tuple(shp):
                    v = v[tuple(slice(0, s) for s in shp)]
            ins.append(v)
        name = op.outputs[0].name
        if spec.impl == "pallas":
            from repro.kernels.ops import attention as attn_kernel
            y = attn_kernel(*ins,
                            causal=op.attrs.get("causal", True),
                            use_kernel="pallas")
        else:
            y = local_apply(op.kind, jnp, ins, op.attrs, spec.out_shape)
        exact[name] = y.astype(dtypes[name])
    outs = []
    for name in live_out:
        pad = out_pads[name]
        y = exact.get(name)
        if y is None:
            outs.append(jnp.zeros(pad, dtypes[name]))
        elif tuple(y.shape) == pad:
            outs.append(y)
        else:
            outs.append(jnp.zeros(pad, dtypes[name]).at[
                tuple(slice(0, s) for s in y.shape)].set(y))
    return tuple(outs)


def emit_segment(seg, tenv, i, *, seg_live, graph: Graph, k: int,
                 shapes, order: DeviceOrder, n_mesh: int) -> None:
    """Emit one compute segment into the traced env ``tenv``: one branch
    per specialization class (straight-line when homogeneous over the
    whole mesh), plus a zero branch when some mesh position idles."""
    import jax
    import jax.numpy as jnp

    live_in, live_out = seg_live[id(seg)]
    if not live_out:
        return              # dead code: nothing escapes
    # shared dtype chain (class-independent: promotion depends only on
    # input dtypes, identical across classes)
    dtypes: dict[str, np.dtype] = {}
    for op in seg.ops:
        dtypes[op.outputs[0].name] = result_dtype(
            op.kind,
            [dtypes.get(t.name, None)
             or np.dtype(tenv[t.name].dtype)
             for t in op.inputs])
    out_pads = {
        n: pad_shape(graph.tensors[n].annots[k], shapes[n])
        for n in live_out}
    args = [tenv[n] for n in live_in]
    n_cls = seg.n_classes
    pos_cls = []
    for p in range(n_mesh):
        c = seg.class_of(order.devices[p]) if p < len(order) else None
        pos_cls.append(n_cls if c is None else c)
    if n_cls == 1 and all(c == 0 for c in pos_cls):
        outs = run_segment_class(seg, seg.classes[0], dtypes, live_in,
                                 live_out, out_pads, args)
    else:
        branches = [
            (lambda cls: lambda *vs: run_segment_class(
                seg, cls, dtypes, live_in, live_out, out_pads, vs))(cls)
            for cls in seg.classes]
        if any(c == n_cls for c in pos_cls):
            branches.append(lambda *vs: tuple(
                jnp.zeros(out_pads[n], dtypes[n]) for n in live_out))
        tbl = jnp.asarray(pos_cls, jnp.int32)
        outs = jax.lax.switch(tbl[i], branches, *args)
    for name, y in zip(live_out, outs):
        tenv[name] = y


def fetch_rows(outs, n_mesh: int) -> list:
    """Per-mesh-position host rows for each fetched device array.

    On the CPU backend each per-device shard is host memory already, so
    ``np.from_dlpack`` views it without the stitch-and-copy that
    ``jax.device_get`` performs on a sharded array (the DLPack capsule
    keeps the jax buffer alive for as long as the views are).  Falls
    back to one bulk ``device_get`` elsewhere."""
    import jax

    try:
        per_out = []
        for out in outs:
            rows: list = [None] * n_mesh
            for sh in out.addressable_shards:
                idx = sh.index[0]
                pos = (idx.start or 0) if isinstance(idx, slice) \
                    else int(idx)
                rows[pos] = np.from_dlpack(sh.data)[0]
            if any(r is None for r in rows):
                raise ValueError("unaddressable shard")
            per_out.append(rows)
        return per_out
    except Exception:
        return [[arr[i] for i in range(n_mesh)]
                for arr in jax.device_get(outs)]


def unpack_rows(graph: Graph, k: int, shapes, order: DeviceOrder,
                name: str, rows: list) -> ShardedTensor:
    """Stacked host rows -> ShardedTensor under ``name``'s annotation
    (parts are views into the rows; callers never mutate shards in
    place)."""
    annot = graph.tensors[name].annots[k]
    shape = shapes[name]
    parts = {
        dev: rows[order.pos(dev)][
            tuple(slice(0, s) for s in annot.device_shape(dev, shape))]
        for dev in annot.devices}
    return ShardedTensor(shape, annot, parts)


class LoweredGraph:
    """A deduced graph + strategy compiled to one shard_map program,
    reusable over fresh shard values without retracing.

    With ``num_microbatches=m > 1`` the SAME program additionally scans
    over a leading microbatch axis: placeholder buffers carry all ``m``
    microbatch shards stacked at axis 1, a ``jax.lax.scan`` runs the
    per-device body (unchanged segment emissions + comm lowerings)
    once per microbatch, and every fetch comes back per-microbatch — the
    pipeline schedule's work, expressed as one XLA program whose
    dependence order realizes the same 1F1B/GPipe overlap.  The graph
    passed in must then be the MICRO graph (shapes already scaled;
    ``Program.compile_micro``).

    Interleaved virtual stages (Megatron's ``v`` chunks per device;
    ``schedule.infer_virtual_stages``) need no special lowering: a
    device holding ``v`` chunks simply belongs to the participant class
    of every one of its chunks' segments, and the wrap-around CommOps
    route activations around the device ring ``v`` times inside the same
    scanned body.  ``n_virtual_stages`` surfaces the deduced chunk
    structure (``n_stages * v``) for introspection — the explicit
    interleaved timetable remains the SimulatorExecutor's contract,
    checked bit-exactly against this program by the
    ``api:pipeline/interleaved*`` selftest cases."""

    def __init__(self, graph: Graph, strategy: int = 0, *,
                 shape_env: dict[str, int] | None = None, mesh=None,
                 topology: Topology | None = None,
                 reduction: str = "exact", fetches=None,
                 num_microbatches: int = 1):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        self.graph = graph
        self.k = strategy
        if num_microbatches < 1:
            raise ValueError(
                f"num_microbatches must be >= 1 (got {num_microbatches})")
        self.num_microbatches = num_microbatches
        env = shape_env or {}
        self.shapes = {name: bind_shape(t.shape, env)
                       for name, t in graph.tensors.items()}
        resolved = resolve_comm_ops(graph, strategy, topology, shape_env)
        self._plans = {id(rc.op): rc.plan for rc in resolved}
        # id -> op, built ONCE (plan lowering below used to re-scan
        # graph.comm_ops per plan — an O(n^2) linear hunt)
        self._comm_op_by_id = {id(op): op for op in graph.comm_ops}
        # kept for the lazy pipeline/chunk introspection properties
        self._resolved_comms = resolved
        self._pipelines: "list | None" = None
        self._pack_bufs: dict[str, np.ndarray] = {}

        devs: set[int] = set()
        for t in graph.tensors.values():
            if t.annots:
                devs |= set(t.annots[strategy].devices)
        for plan in self._plans.values():
            for annot in plan.annots:
                devs |= set(annot.devices)
        self.order = DeviceOrder(tuple(sorted(devs)))

        if mesh is None:
            from repro.launch.mesh import make_runtime_mesh
            mesh = make_runtime_mesh(len(self.order))
        self.mesh = mesh
        self.n_mesh = int(mesh.devices.size)
        if self.n_mesh < len(self.order):
            raise ValueError(
                f"graph spans {len(self.order)} logical devices but mesh "
                f"has only {self.n_mesh}; force more host devices (e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{len(self.order)})")
        axis = mesh.axis_names[0]

        self.leaves = [o.outputs[0] for o in graph.ops
                       if o.kind in ("placeholder", "parameter")]
        self.fetches = list(fetches or [t.name for t in graph.sinks()])
        for f in self.fetches:
            if f not in graph.tensors:
                raise ValueError(f"unknown fetch tensor {f!r}")

        self.stats = LoweringStats()
        lowerings: dict[int, PlanLowering] = {}
        needs_x64 = False
        for oid, op in self._comm_op_by_id.items():
            plan = self._plans[oid]
            shape = self.shapes[op.inputs[0].name]
            pl = PlanLowering(plan, shape, self.order, axis, self.n_mesh,
                              reduction=reduction)
            lowerings[oid] = pl
            self.stats.merge(pl.stats)
            needs_x64 |= pl.needs_x64

        # Kernel dispatch is decided STATICALLY, per specialization
        # class, from the device-LOCAL shard shapes — a TP-split head
        # dim can make a shard kernel-eligible (or not) independent of
        # the global shape.  Devices whose shard shapes agree share ONE
        # decision (kernels.policy memoizes per distinct shape pair),
        # and the decision participates in the class partition: same
        # shapes but different impls would be different classes.
        k, shapes = strategy, self.shapes

        def impl_of(op, dev):
            if op.kind != "attention":
                return ""
            qs = shapes[op.inputs[0].name]
            ks = shapes[op.inputs[1].name]
            return select_attention_impl_per_class(
                tuple(op.inputs[0].annots[k].device_shape(dev, qs)),
                tuple(op.inputs[1].annots[k].device_shape(dev, ks)))

        self.ir = partition_graph(graph, strategy, shapes=shapes,
                                  impl_of=impl_of,
                                  devices=self.order.devices)

        # static per-segment liveness (shared helper; also used by the
        # per-stage async lowering)
        self._seg_live = segment_liveness(graph, self.ir.segments,
                                          self.fetches)

        # branch accounting: the structural win the benchmark records.
        # A homogeneous segment (one class, every mesh position) is
        # straight-line — zero switches; anything else emits one branch
        # per class (+ one zero branch when some position idles).
        extra_idle = self.n_mesh > len(self.order)
        for seg in self.ir.segments:
            if not self._seg_live[id(seg)][1]:
                continue                    # dead segment: never emitted
            self.stats.compute_segments += 1
            if seg.is_homogeneous() and not extra_idle:
                self.stats.straightline_segments += 1
            else:
                idle = 1 if (seg.idle_devices or extra_idle) else 0
                self.stats.switch_branches_emitted += \
                    seg.n_classes + idle
            for cls in seg.classes:
                for op, spec in zip(seg.ops, cls.specs):
                    if op.kind == "attention" and spec is not None:
                        if spec.impl == "pallas":
                            self.stats.pallas_dispatches += 1
                        else:
                            self.stats.ref_dispatches += 1

        order, n_mesh = self.order, self.n_mesh
        seg_live = self._seg_live

        # placeholders carry a per-microbatch axis in microbatched mode;
        # parameters are microbatch-invariant and stay single-buffer
        self._per_mb = {t.name for t in self.leaves
                        if t.producer is not None
                        and t.producer.kind == "placeholder"}
        m = num_microbatches
        entries = self.ir.entries

        def eval_ops(tenv, i):
            import jax.numpy as jnp

            # single-stage uniform reduces (the grad-reduce common case)
            # are DEFERRED and batched: one fused multi-operand psum per
            # distinct group partition instead of one collective per
            # comm op — collectives on a host mesh are latency-bound,
            # so rendezvous count is what matters.  A deferred value is
            # flushed the moment a segment or comm op consumes it; the
            # fold order per group is unchanged, so results stay
            # bit-identical to one-at-a-time emission.
            deferred: dict[str, tuple] = {}

            def flush(names=None):
                todo = [(n, deferred.pop(n)) for n in
                        (list(deferred) if names is None else names)
                        if n in deferred]
                by_key: dict[tuple, list] = {}
                for name, item in todo:
                    pl, uni, x, od = item
                    # fast mode and two-source exact groups both run a
                    # native-dtype psum (for k<=2 it IS the f64 fold
                    # cast back, bitwise); only larger exact groups
                    # need the ordered float64 fold
                    path = "psum" if pl.reduction == "fast" \
                        or uni["k"] <= 2 else "fold"
                    key = (tuple(tuple(g) for g in uni["groups"]), path)
                    by_key.setdefault(key, []).append((name,) + item)
                for (gk, path), items in by_key.items():
                    if path == "fold":
                        for name, pl, uni, x, od in items:
                            tenv[name] = pl._emit_uniform_stage(x, uni,
                                                                od)
                        continue
                    contribs = [x[uni["src_rel"]]
                                for name, pl, uni, x, od in items]
                    # one flat buffer -> ONE all-reduce (a variadic
                    # psum is split back per operand by XLA); summing
                    # the concatenation is elementwise, so results are
                    # bitwise those of per-op collectives
                    dt = jnp.result_type(*(c.dtype for c in contribs))
                    flat = jnp.concatenate(
                        [c.astype(dt).ravel() for c in contribs]) \
                        if len(contribs) > 1 else contribs[0]
                    y_all = jax.lax.psum(
                        flat, axis,
                        axis_index_groups=[list(g) for g in gk])
                    off = 0
                    for (name, pl, uni, x, od), c in zip(items,
                                                         contribs):
                        if len(contribs) == 1:
                            y = y_all
                        else:
                            n = int(np.prod(c.shape)) if c.shape else 1
                            y = y_all[off:off + n].reshape(
                                c.shape).astype(c.dtype)
                            off += n
                        tenv[name] = jnp.zeros(uni["next_pad"], od).at[
                            uni["dst_rel"]].set(
                                y[uni["piece_rel"]].astype(od))

            for entry in entries:
                if isinstance(entry, CommSlot):
                    op = entry.op
                    in_name = op.inputs[0].name
                    if in_name in deferred:
                        flush([in_name])
                    x = tenv[in_name]
                    pl = lowerings[id(op)]
                    unis = pl._uniform_stages
                    if len(unis) == 1 and unis[0] is not None \
                            and unis[0]["kind"] == "reduce":
                        deferred[op.outputs[0].name] = \
                            (pl, unis[0], x, x.dtype)
                    else:
                        tenv[op.outputs[0].name] = pl.apply(x, i,
                                                            x.dtype)
                else:
                    live_in, _ = self._seg_live[id(entry)]
                    pend = [n for n in live_in if n in deferred]
                    if pend:
                        flush(pend)
                    emit_segment(entry, tenv, i, seg_live=seg_live,
                                 graph=graph, k=k, shapes=shapes,
                                 order=order, n_mesh=n_mesh)
            flush()
            return tenv

        def body(*blocks):
            i = jax.lax.axis_index(axis)
            if m == 1:
                tenv = {t.name: b[0] for t, b in zip(self.leaves, blocks)}
                tenv = eval_ops(tenv, i)
                return tuple(tenv[f][None] for f in self.fetches)
            shared = {t.name: b[0] for t, b in zip(self.leaves, blocks)
                      if t.name not in self._per_mb}
            xs = {t.name: b[0] for t, b in zip(self.leaves, blocks)
                  if t.name in self._per_mb}          # (m, *pad) each

            def mb_body(carry, x_j):
                tenv = eval_ops({**shared, **x_j}, i)
                return carry, tuple(tenv[f] for f in self.fetches)

            _, ys = jax.lax.scan(mb_body, 0, xs, length=m)  # ys (m, *pad)
            return tuple(y[None] for y in ys)

        def leaf_rank(t):
            rank = len(shapes[t.name])
            return rank + 1 if m > 1 and t.name in self._per_mb else rank

        in_specs = tuple(P(axis, *([None] * leaf_rank(t)))
                         for t in self.leaves)
        out_rank = {f: len(shapes[f]) + (1 if m > 1 else 0)
                    for f in self.fetches}
        out_specs = tuple(P(axis, *([None] * out_rank[f]))
                          for f in self.fetches)
        jitted = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=False))
        self.fn = maybe_x64(jitted, needs_x64 and reduction == "exact")

    # -- introspection (lazy: not on the lowering/execution path) ----------

    @property
    def pipelines(self):
        """Deduced pipeline structure (shares the lowering's comm
        resolution); computed on first access."""
        if self._pipelines is None:
            from repro.core.specialize import construct_pipelines
            self._pipelines = construct_pipelines(
                self.graph, self.k, resolved_comms=self._resolved_comms)
        return self._pipelines

    @property
    def n_stages(self) -> int:
        return max((p.n_stages for p in self.pipelines), default=1)

    @property
    def n_virtual_stages(self) -> int:
        """Physical stages * interleave chunks (Megatron's ``S * v``)."""
        from repro.core.schedule import infer_virtual_stages
        return self.n_stages * infer_virtual_stages(
            self.graph, self.k, self.pipelines)

    # -- pack / unpack -----------------------------------------------------

    def _pack(self, st: ShardedTensor, annot, shape,
              buf_key: str | None = None) -> np.ndarray:
        # leaf blocks are re-packed every step with identical geometry;
        # keyed buffers skip the zeroed allocation (safe: device_put
        # copies into per-device buffers before the next pack runs)
        out = self._pack_bufs.get(buf_key) if buf_key else None
        stacked = pack_shards(st.parts, annot, shape, self.n_mesh,
                              self.order, out=out)
        if buf_key:
            self._pack_bufs[buf_key] = stacked
        return stacked

    def _put(self, stacked: np.ndarray):
        return self._put_all([stacked])[0]

    def _put_all(self, blocks: list[np.ndarray]):
        """One batched ``device_put`` for all leaf blocks."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        axis = self.mesh.axis_names[0]
        shardings = [
            NamedSharding(self.mesh, P(axis, *([None] * (b.ndim - 1))))
            for b in blocks]
        return jax.device_put(blocks, shardings)

    def _unpack(self, name: str, rows: list) -> ShardedTensor:
        return unpack_rows(self.graph, self.k, self.shapes, self.order,
                           name, rows)

    def _fetch_rows(self, outs) -> list:
        return fetch_rows(outs, self.n_mesh)

    def run(self, state: dict[str, ShardedTensor]
            ) -> dict[str, ShardedTensor]:
        """Execute once; ``state`` maps every leaf name (placeholder AND
        parameter) to its ShardedTensor under the strategy annotation."""
        if self.num_microbatches != 1:
            raise ValueError("microbatched program: use run_microbatches")
        blocks = []
        for t in self.leaves:
            if t.name not in state:
                raise ValueError(f"missing leaf tensor {t.name!r}")
            annot = t.annots[self.k]
            blocks.append(self._pack(
                state[t.name], annot, self.shapes[t.name],
                buf_key=t.name))
        outs = self._fetch_rows(self.fn(*self._put_all(blocks)))
        return {name: self._unpack(name, rows)
                for name, rows in zip(self.fetches, outs)}

    def run_microbatches(self, states: list[dict[str, ShardedTensor]]
                         ) -> list[dict[str, ShardedTensor]]:
        """Execute the scanned program over ``num_microbatches`` leaf
        states (microbatch ``j``'s placeholders in ``states[j]``;
        parameters read from ``states[0]``).  Returns per-microbatch
        fetches, bit-comparable to ``SimulatorExecutor.run_schedule``."""
        m = self.num_microbatches
        if m == 1:
            raise ValueError("unpipelined program: use run")
        if len(states) != m:
            raise ValueError(
                f"{len(states)} microbatch states for a {m}-microbatch "
                f"program")
        blocks = []
        for t in self.leaves:
            annot = t.annots[self.k]
            shape = self.shapes[t.name]
            if t.name in self._per_mb:
                for st in states:
                    if t.name not in st:
                        raise ValueError(
                            f"missing leaf tensor {t.name!r}")
                blocks.append(np.stack(
                    [self._pack(st[t.name], annot, shape,
                                buf_key=f"{t.name}#{j}")
                     for j, st in enumerate(states)], axis=1))
            else:
                if t.name not in states[0]:
                    raise ValueError(f"missing leaf tensor {t.name!r}")
                blocks.append(self._pack(states[0][t.name], annot,
                                         shape, buf_key=t.name))
        outs = self._fetch_rows(self.fn(*self._put_all(blocks)))
        results: list[dict[str, ShardedTensor]] = [{} for _ in range(m)]
        for name, rows in zip(self.fetches, outs):
            for j in range(m):                  # rows[pos] (m, *pad)
                results[j][name] = self._unpack(
                    name, [r[j] for r in rows])
        return results


def plan_input_name(graph: Graph, op_id: int) -> str:
    """Input tensor name of the CommOp with ``id(op) == op_id``.

    Kept for external callers; ``LoweredGraph`` itself builds the
    id -> op map once instead of re-scanning per plan."""
    by_id = {id(op): op for op in graph.comm_ops}
    try:
        return by_id[op_id].inputs[0].name
    except KeyError:
        raise KeyError(op_id) from None


def lower_graph(graph: Graph, strategy: int = 0, *,
                shape_env: dict[str, int] | None = None, mesh=None,
                topology: Topology | None = None, reduction: str = "exact",
                fetches=None, num_microbatches: int = 1) -> LoweredGraph:
    """Compile a deduced graph for one strategy; see :class:`LoweredGraph`."""
    return LoweredGraph(graph, strategy, shape_env=shape_env, mesh=mesh,
                        topology=topology, reduction=reduction,
                        fetches=fetches, num_microbatches=num_microbatches)
