"""Real-device execution backend for resolved HSPMD communication plans.

``core.comm_resolve`` turns annotation pairs into :class:`CommPlan`s and
``core.simulator`` validates them numerically on virtual devices; this
package lowers the same plans onto *real* JAX devices — every CommStep
kind becomes ``jax.lax`` collectives / ``ppermute`` inside one
``jax.shard_map`` program, per-device specialized via ``lax.switch``
(paper §5.3).  ``runtime.diff`` checks every executed plan bit-exactly
against the simulator; ``runtime.harness`` forces N CPU host devices so
all of it runs anywhere.
"""

from .backend import (CompiledPlan, compile_plan, device_items,
                      execute_graph, execute_plan, execute_sharded,
                      resharding_fn)
from .diff import differential_check, integer_decompose, roundtrip_check
from .harness import ensure_host_devices, host_device_env, run_subprocess
from .lowering import (DeviceOrder, LoweringStats, PlanLowering, lower_plan,
                       pad_shape)
from .program import LoweredGraph, lower_graph

__all__ = [
    "CompiledPlan", "DeviceOrder", "LoweredGraph", "LoweringStats",
    "PlanLowering", "compile_plan", "device_items", "differential_check",
    "ensure_host_devices", "execute_graph", "execute_plan",
    "execute_sharded", "host_device_env", "integer_decompose",
    "lower_graph", "lower_plan", "pad_shape", "resharding_fn",
    "roundtrip_check", "run_subprocess",
]
