"""Execution backend: run resolved communication plans on real JAX devices.

Public surface:

* :func:`execute_plan` — run a :class:`CommPlan` over per-device numpy
  shards and return the destination shards (all data actually moves
  through XLA collectives under ``jax.shard_map``),
* :func:`execute_sharded` — the same, adapted to the simulator's
  :class:`~repro.core.simulator.ShardedTensor` (drop-in replacement for
  ``simulator.apply_plan``),
* :func:`resharding_fn` — resolve (src, dst) once and return a reusable
  migration function, caching the compiled program per global shape,
* :func:`device_items` — the per-device :class:`ExecItem` view of a plan
  (what progressive specialization hands each device; paper §5.3).
"""

from __future__ import annotations

import numpy as np

from repro.core.annotations import HSPMD
from repro.core.plan import CommPlan, box_shape
from repro.core.simulator import ShardedTensor
from repro.core.specialize import ExecItem
from repro.core.topology import Topology

from .lowering import (DeviceOrder, LoweringStats, lower_plan, pack_shards,
                       pad_shape)


def _default_mesh(n: int):
    from repro.launch.mesh import make_runtime_mesh
    return make_runtime_mesh(n)


class CompiledPlan:
    """A plan lowered once for a (mesh, shape, reduction); reusable over
    fresh shard values without retracing."""

    def __init__(self, plan: CommPlan, shape: tuple[int, ...], mesh, *,
                 reduction: str = "exact"):
        if plan.src is None:
            raise ValueError("plan has no source annotation")
        self.plan = plan
        self.shape = tuple(shape)
        self.mesh = mesh
        self.order = DeviceOrder.for_plan(plan)
        self.n_mesh = int(mesh.devices.size)
        if self.n_mesh < len(self.order):
            raise ValueError(
                f"plan spans {len(self.order)} logical devices but mesh "
                f"has only {self.n_mesh}; force more host devices (e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{len(self.order)})")
        self.stats = LoweringStats()
        self.fn = lower_plan(plan, self.shape, mesh, self.order,
                             reduction=reduction, stats_out=self.stats)

    def _pack(self, parts: dict[int, np.ndarray]) -> np.ndarray:
        return pack_shards(parts, self.plan.src, self.shape, self.n_mesh,
                           self.order)

    def _unpack(self, out: np.ndarray) -> dict[int, np.ndarray]:
        dst = self.plan.annots[-1]
        result: dict[int, np.ndarray] = {}
        for dev in dst.devices:
            bshape = box_shape(dst.device_box(dev, self.shape))
            result[dev] = out[(self.order.pos(dev),)
                              + tuple(slice(0, s) for s in bshape)].copy()
        return result

    def __call__(self, parts: dict[int, np.ndarray]) -> dict[int, np.ndarray]:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = P(self.mesh.axis_names[0], *([None] * len(self.shape)))
        inp = jax.device_put(self._pack(parts),
                             NamedSharding(self.mesh, spec))
        return self._unpack(np.asarray(self.fn(inp)))


def compile_plan(plan: CommPlan, shape: tuple[int, ...], mesh=None, *,
                 reduction: str = "exact") -> CompiledPlan:
    mesh = mesh or _default_mesh(len(DeviceOrder.for_plan(plan)))
    return CompiledPlan(plan, shape, mesh, reduction=reduction)


def execute_plan(plan: CommPlan, parts: dict[int, np.ndarray],
                 shape: tuple[int, ...], mesh=None, *,
                 reduction: str = "exact") -> dict[int, np.ndarray]:
    """Execute ``plan`` on real devices; ``parts`` maps each source device
    to its local shard (shaped by ``plan.src.device_box``)."""
    return compile_plan(plan, shape, mesh, reduction=reduction)(parts)


def execute_sharded(st: ShardedTensor, plan: CommPlan, mesh=None, *,
                    reduction: str = "exact") -> ShardedTensor:
    """``simulator.apply_plan`` signature-compatible real-device execution."""
    parts = execute_plan(plan, st.parts, st.shape, mesh,
                         reduction=reduction)
    return ShardedTensor(st.shape, plan.annots[-1], parts)


def resharding_fn(src_annot: HSPMD, dst_annot: HSPMD, mesh=None, *,
                  topology: Topology | None = None,
                  reduction: str = "exact"):
    """Resolve (src, dst) and return ``fn(parts, shape) -> parts`` that
    migrates shards on real devices; the plan AND its lowered shard_map
    program are cached per global shape (repeat migrations don't
    retrace)."""
    from repro.core.comm_resolve import resolve

    plans: dict[tuple[int, ...], CompiledPlan] = {}

    def fn(parts: dict[int, np.ndarray],
           shape: tuple[int, ...]) -> dict[int, np.ndarray]:
        shape = tuple(int(s) for s in shape)
        compiled = plans.get(shape)
        if compiled is None:
            plan = resolve(src_annot, dst_annot, shape, topology)
            compiled = plans[shape] = compile_plan(plan, shape, mesh,
                                                   reduction=reduction)
        return compiled(parts)

    fn.plans = plans
    return fn


def execute_graph(graph, strategy: int = 0, *, state=None, mesh=None,
                  shape_env=None, topology=None, reduction: str = "exact",
                  fetches=None) -> dict[str, ShardedTensor]:
    """Execute a deduced graph's compute AND comm ExecItems end-to-end on
    real devices under one ``shard_map`` program (see ``runtime.program``).

    ``state`` maps every leaf tensor name (placeholders + parameters) to
    its :class:`ShardedTensor`; returns the fetched tensors (default: the
    graph's sinks) as ShardedTensors under their deduced annotations.
    """
    from .program import lower_graph
    lowered = lower_graph(graph, strategy, shape_env=shape_env, mesh=mesh,
                          topology=topology, reduction=reduction,
                          fetches=fetches)
    return lowered.run(state or {})


def device_items(plan: CommPlan, device: int, name: str = "comm") -> list[ExecItem]:
    """The ExecItems ``device`` executes for this plan — identical filtering
    to :func:`repro.core.specialize.specialize`'s CommOp substitution."""
    items = []
    for stage in plan.stages:
        for step in stage.steps:
            mine = [g for g in step.groups
                    if device in g.srcs or device in g.dsts]
            if mine or (step.kind in ("ID", "Slice")
                        and device in stage.annot_after.devices):
                items.append(ExecItem(step.kind, name, "comm",
                                      f"{len(mine)} group(s)"))
    return items
