"""Lower a :class:`~repro.core.plan.CommPlan` onto real JAX devices.

The simulator executes plans on a ``dict[device, np.ndarray]``; this module
compiles the *same* stage semantics into one ``jax.shard_map`` program over
a 1-D device mesh, so every resolved communication operator actually moves
tensors through XLA collectives:

* copy groups (SR / AG / SplitAG / BSR) — point-to-point deliveries are
  **fused into batched permutes**: all (src, dst) pairs of a stage are
  packed into rounds (each source and each destination used at most once
  per round) and every round becomes ONE ``jax.lax.ppermute`` over
  padded slabs, instead of one collective launch per pair.  The static
  round schedule is reported in :class:`LoweringStats`,
* reduce groups (AR / RS / SplitAR / SplitRS) — run as **subgroup
  collectives** via ``axis_index_groups`` whenever every destination is
  a source (non-participant mesh positions ride along as dummy partition
  entries; see ``PlanLowering._reduce_groups_static``), falling back to
  the masked full-axis form otherwise:
  - ``reduction="exact"``: ``jax.lax.all_gather`` of the per-source
    contributions, then a left fold in float64 following the group's
    ``srcs`` order.  This reproduces ``simulator.apply_plan`` **bit
    exactly** for arbitrary inputs (the simulator accumulates in float64
    in the same order before casting back),
  - ``reduction="fast"``: a single ``jax.lax.psum`` in the native
    dtype (a real all-reduce; bit-exact only when the data makes the sum
    order-insensitive, e.g. integer-valued shards),
* ID / Slice — no collective; covered by the local-retention path.

Per-device specialization (paper §5.3) is realized literally: the stage
state update is a ``jax.lax.switch`` over ``axis_index`` whose branches are
the per-device programs — each branch only writes the slice-group
deliveries that device participates in, mirroring
:func:`repro.core.specialize.specialize`.

Because every device can hold a differently-shaped box (heterogeneous
``hsplits``), local shards are padded to the per-stage elementwise-max box
shape; geometry is static, so stage coverage is checked at lowering time
with the same strictness as the simulator.

:class:`PlanLowering` is the reusable core: it applies one plan's stages
to a device-local padded value *inside an enclosing shard_map body*, so
the whole-graph executor (``runtime.program``) can interleave comm plans
with per-device compute.  :func:`lower_plan` wraps it into a standalone
jitted program.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.annotations import HSPMD
from repro.core.plan import (Box, CommPlan, box_contains, box_intersect,
                             box_shape, rel_slices)

REDUCTIONS = ("exact", "fast")


@dataclass(frozen=True)
class DeviceOrder:
    """Mapping between logical HSPMD device ids and mesh axis positions."""

    devices: tuple[int, ...]

    @classmethod
    def for_plan(cls, plan: CommPlan) -> "DeviceOrder":
        devs = set()
        if plan.src is not None:
            devs |= set(plan.src.devices)
        for annot in plan.annots:
            devs |= set(annot.devices)
        for step in plan.steps:
            for g in step.groups:
                devs |= set(g.srcs) | set(g.dsts)
        return cls(tuple(sorted(devs)))

    def pos(self, dev: int) -> int:
        return self.devices.index(dev)

    def __len__(self) -> int:
        return len(self.devices)


@dataclass
class LoweringStats:
    """Static collective-launch accounting of one lowered plan, plus the
    kernel-dispatch tallies of the compute seam (``runtime.program``):
    how many per-device attention ExecItems lowered onto the Pallas
    flash kernel vs the pure-XLA reference (``kernels.policy``)."""

    copy_pairs: int = 0      # point-to-point (src, dst) deliveries
    ppermute_calls: int = 0  # batched permutes emitted after fusion
    reduce_groups: int = 0   # all_gather / psum launches
    grouped_reduces: int = 0  # of which run on axis_index_groups subgroups
    uniform_reduce_stages: int = 0  # stages emitted switch-free + fused
    uniform_copy_stages: int = 0    # ident/gather stages emitted switch-free
    stages: int = 0
    ref_dispatches: int = 0     # attention classes on the XLA reference
    pallas_dispatches: int = 0  # attention classes on the Pallas kernels
    # specialization-class emission accounting (core.lowered_ir):
    compute_segments: int = 0       # live compute segments emitted
    straightline_segments: int = 0  # of which needed ZERO switches
    switch_branches_emitted: int = 0  # total class (+idle) branches

    def merge(self, other: "LoweringStats") -> None:
        self.copy_pairs += other.copy_pairs
        self.ppermute_calls += other.ppermute_calls
        self.reduce_groups += other.reduce_groups
        self.grouped_reduces += other.grouped_reduces
        self.uniform_reduce_stages += other.uniform_reduce_stages
        self.uniform_copy_stages += other.uniform_copy_stages
        self.stages += other.stages
        self.ref_dispatches += other.ref_dispatches
        self.pallas_dispatches += other.pallas_dispatches
        self.compute_segments += other.compute_segments
        self.straightline_segments += other.straightline_segments
        self.switch_branches_emitted += other.switch_branches_emitted


def pack_shards(parts, annot: HSPMD, shape: tuple[int, ...], n_mesh: int,
                order: DeviceOrder, out: "np.ndarray | None" = None
                ) -> np.ndarray:
    """Stack per-device shards into the runtime's ``(n_mesh, *pad)``
    buffer (each device's box zero-padded at the origin), validating
    every shard's shape against the annotation and promoting dtypes.

    ``out`` may pass a buffer from a PREVIOUS pack of the same tensor
    to fill in place (skips the zeroed allocation; the padding region
    is never written, so it stays zero from the first pack).  It is
    used only when its shape and dtype still match."""
    dtype = None
    for dev in annot.devices:
        arr = np.asarray(parts[dev])
        want = annot.device_shape(dev, shape)
        if tuple(arr.shape) != tuple(want):
            raise ValueError(
                f"dev {dev}: shard shape {arr.shape} != {want} "
                f"expected by the annotation")
        dtype = arr.dtype if dtype is None else \
            np.promote_types(dtype, arr.dtype)
    full = (n_mesh,) + pad_shape(annot, shape)
    if out is not None and out.shape == full and out.dtype == dtype:
        stacked = out
    else:
        stacked = np.zeros(full, dtype=dtype)
    for dev in annot.devices:
        arr = np.asarray(parts[dev])
        stacked[(order.pos(dev),)
                + tuple(slice(0, s) for s in arr.shape)] = arr
    return stacked


def pad_shape(annot: HSPMD, shape: tuple[int, ...]) -> tuple[int, ...]:
    """Elementwise max of the per-device box shapes (uniform local buffer)."""
    dims = [1] * len(shape)
    for dev in annot.devices:
        for d, s in enumerate(annot.device_shape(dev, shape)):
            dims[d] = max(dims[d], s)
    return tuple(dims)


def check_stage_coverage(prev: HSPMD, nxt: HSPMD,
                         deliveries: list[tuple[Box, tuple[int, ...]]],
                         shape: tuple[int, ...], kinds: str) -> None:
    """Static replica of the simulator's strict coverage assertion."""
    for dev in nxt.devices:
        box = nxt.device_box(dev, shape)
        covered = np.zeros(box_shape(box), dtype=bool)
        if dev in prev.devices:
            inter = box_intersect(prev.device_box(dev, shape), box)
            if inter is not None:
                covered[rel_slices(box, inter)] = True
        for dbox, dsts in deliveries:
            if dev not in dsts:
                continue
            inter = box_intersect(dbox, box)
            if inter is not None:
                covered[rel_slices(box, inter)] = True
        if not covered.all():
            raise AssertionError(
                f"dev {dev}: {int((~covered).sum())} uncovered elements "
                f"after stage [{kinds}]")


@dataclass
class _Round:
    """One batched permute: (src, dst) pairs with distinct srcs and dsts."""

    pairs: list[tuple[int, int, object]] = field(default_factory=list)
    srcs: set[int] = field(default_factory=set)
    dsts: set[int] = field(default_factory=set)

    def add(self, s: int, d: int, g) -> None:
        self.pairs.append((s, d, g))
        self.srcs.add(s)
        self.dsts.add(d)


def _fuse_rounds(pairs: list[tuple[int, int, object]]) -> list[_Round]:
    """Greedy round construction: each round uses every source and every
    destination at most once (ppermute's partial-permutation contract)."""
    rounds: list[_Round] = []
    for s, d, g in pairs:
        for r in rounds:
            if s not in r.srcs and d not in r.dsts:
                r.add(s, d, g)
                break
        else:
            r = _Round()
            r.add(s, d, g)
            rounds.append(r)
    return rounds


class PlanLowering:
    """Applies one CommPlan's stages to a device-local padded value inside
    an enclosing ``shard_map`` body.

    All geometry (boxes, fusion rounds, coverage) is computed and checked
    statically at construction; :meth:`apply` only emits traced ops.
    """

    def __init__(self, plan: CommPlan, shape: tuple[int, ...],
                 order: DeviceOrder, axis: str, n_mesh: int, *,
                 reduction: str = "exact", fuse_permutes: bool = True):
        if reduction not in REDUCTIONS:
            raise ValueError(f"reduction must be one of {REDUCTIONS}")
        if plan.src is None:
            raise ValueError("plan has no source annotation")
        if n_mesh < len(order):
            raise ValueError(
                f"plan spans {len(order)} logical devices but mesh has "
                f"only {n_mesh}; force more host devices (e.g. "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{len(order)})")
        self.plan = plan
        self.shape = tuple(shape)
        self.order = order
        self.axis = axis
        self.n_mesh = n_mesh
        self.reduction = reduction
        # fuse_permutes=False is the GSPMD-resharding baseline for the
        # overlap micro-benchmark: every (src, dst) copy becomes its own
        # single-pair ppermute round and the uniform switch-free fast
        # paths are disabled, so each delivery is a separate collective
        # launch (same bits, more launches)
        self.fuse_permutes = fuse_permutes
        self.stats = LoweringStats()
        self.has_reduce = any(g.reduce for s in plan.steps for g in s.groups)
        # set while walking the groups below: exact mode only needs the
        # float64 fold machinery for groups of MORE than two sources (a
        # two-operand group's exact-fold-then-cast IS the native-dtype
        # psum bitwise) or groups that cannot run on a psum subgroup
        self.needs_x64 = False

        # static geometry per stage, verified up front; copy deliveries
        # fused into batched-permute rounds, reduce groups mapped onto
        # axis_index_groups subgroup collectives where possible
        self._stage_rounds: list[list[_Round]] = []
        self._reduce_partitions: dict[int, tuple] = {}
        self._uniform_stages: list[dict | None] = []
        prev = plan.src
        for stage in plan.stages:
            uni = (self._uniform_stage_static(stage, prev)
                   or self._uniform_ident_static(stage, prev)
                   or self._uniform_gather_static(stage, prev)) \
                if fuse_permutes else None
            self._uniform_stages.append(uni)
            if uni is not None:
                if uni["kind"] == "reduce":
                    self.stats.uniform_reduce_stages += 1
                else:
                    self.stats.uniform_copy_stages += 1
            deliveries = [(g.box, g.dsts) for step in stage.steps
                          for g in step.groups]
            pairs = []
            for step in stage.steps:
                for g in step.groups:
                    for s in g.srcs:
                        sbox = prev.device_box(s, self.shape)
                        if not box_contains(sbox, g.box):
                            raise AssertionError(
                                f"src dev {s} box {sbox} does not contain "
                                f"group box {g.box}")
                    if g.reduce:
                        self.stats.reduce_groups += 1
                        part = self._reduce_groups_static(g)
                        self._reduce_partitions[id(g)] = part
                        if part[0 if reduction == "fast" else 1]:
                            self.stats.grouped_reduces += 1
                        if len(g.srcs) > 2 or part[0] is None:
                            self.needs_x64 = True
                        continue
                    src = g.srcs[0]
                    for d in g.dsts:
                        if d != src:
                            pairs.append((src, d, g))
            kinds = "+".join(st.kind for st in stage.steps)
            check_stage_coverage(prev, stage.annot_after, deliveries,
                                 self.shape, kinds)
            if fuse_permutes:
                rounds = _fuse_rounds(pairs)
            else:               # GSPMD-style: one ppermute per pair
                rounds = []
                for s, d, g in pairs:
                    r = _Round()
                    r.add(s, d, g)
                    rounds.append(r)
            self._stage_rounds.append(rounds)
            if uni is None:    # uniform stages never emit the rounds
                self.stats.copy_pairs += len(pairs)
                self.stats.ppermute_calls += len(rounds)
            self.stats.stages += 1
            prev = stage.annot_after

    def _uniform_stage_static(self, stage, prev) -> dict | None:
        """Static descriptor of a *uniform reduce stage* — the symmetric
        case where every mesh position plays the identical role, so the
        stage lowers switch-free with ONE fused collective:

        * every group is a reduce whose destinations equal its sources,
        * the groups' source positions partition the whole mesh axis
          into equal-size subgroups,
        * every source extracts the same slice of its local padded
          buffer (regular tilings make the extract position-invariant
          in *local* coordinates even though the global boxes differ),
        * every destination's next-annotation box is fully covered by
          its group's box, at the same local offsets.

        This is the comm-side analogue of the compute segments' single
        specialization class: per-device ``lax.switch`` emission (and
        one collective per group) collapses to straight-line code with
        a single ``axis_index_groups`` collective for all groups.
        Returns ``None`` when any condition fails (masked per-group
        emission is kept as the general path)."""
        groups = [g for step in stage.steps for g in step.groups]
        if not groups or not all(g.reduce for g in groups):
            return None
        if any(set(g.dsts) != set(g.srcs) for g in groups):
            return None
        k = len(groups[0].srcs)
        if any(len(g.srcs) != k for g in groups):
            return None
        pos_groups = [[self.order.pos(s) for s in g.srcs] for g in groups]
        flat = sorted(p for ps in pos_groups for p in ps)
        if flat != list(range(self.n_mesh)):
            return None
        gshape = box_shape(groups[0].box)
        src_rel = None
        for g in groups:
            if box_shape(g.box) != gshape:
                return None
            for s in g.srcs:
                r = rel_slices(prev.device_box(s, self.shape), g.box)
                if src_rel is None:
                    src_rel = r
                elif r != src_rel:
                    return None
        nxt = stage.annot_after
        if set(nxt.devices) != set(self.order.devices):
            return None
        dst_rel = piece_rel = nbox_shape = None
        for g in groups:
            for dev in g.dsts:
                nbox = nxt.device_box(dev, self.shape)
                inter = box_intersect(g.box, nbox)
                if inter != nbox:   # piece must fully cover the dst box
                    return None
                d_r = rel_slices(nbox, inter)
                p_r = rel_slices(g.box, inter)
                bs = box_shape(nbox)
                if dst_rel is None:
                    dst_rel, piece_rel, nbox_shape = d_r, p_r, bs
                elif (d_r, p_r, bs) != (dst_rel, piece_rel, nbox_shape):
                    return None
        return {"kind": "reduce", "src_rel": src_rel, "groups": pos_groups,
                "k": k, "dst_rel": dst_rel, "piece_rel": piece_rel,
                "next_pad": pad_shape(nxt, self.shape)}

    @staticmethod
    def _has_partial(annot) -> bool:
        from repro.core.annotations import PARTIAL
        return annot.hdim == PARTIAL or \
            any(ds.has_partial for ds in annot.dss)

    def _uniform_ident_static(self, stage, prev) -> dict | None:
        """Static descriptor of a *uniform identity stage* — no
        deliveries at all: every device re-slices data it already
        holds, with the same local output shape everywhere.  Only the
        slice OFFSETS vary per mesh position (DP slab selection, TP
        column selection), so per-device ``lax.switch`` emission
        collapses to one ``dynamic_slice`` driven by a position-indexed
        offset table — zero branches, zero collectives.  Excludes
        Partial layouts: a Partial shard is a summand, and re-slicing
        summands is only meaningful through a reduce stage."""
        if any(step.groups for step in stage.steps):
            return None
        if len(self.order) != self.n_mesh:
            return None
        nxt = stage.annot_after
        if set(nxt.devices) != set(self.order.devices):
            return None
        if not set(self.order.devices) <= set(prev.devices):
            return None
        if self._has_partial(prev) or self._has_partial(nxt):
            return None
        out_shape = None
        starts: list = [None] * self.n_mesh
        for dev in self.order.devices:
            pbox = prev.device_box(dev, self.shape)
            nbox = nxt.device_box(dev, self.shape)
            if box_intersect(pbox, nbox) != nbox:
                return None      # output not locally available
            bs = box_shape(nbox)
            if out_shape is None:
                out_shape = bs
            elif bs != out_shape:
                return None
            r = rel_slices(pbox, nbox)
            starts[self.order.pos(dev)] = tuple(s.start for s in r)
        if out_shape != pad_shape(nxt, self.shape):
            return None
        return {"kind": "ident", "starts": starts, "out_shape": out_shape}

    def _uniform_gather_static(self, stage, prev) -> dict | None:
        """Static descriptor of a *uniform gather stage*: pure copy
        deliveries where every device contributes its (identical-shape)
        local shard and assembles its next box from ``k`` such pieces
        at identical destination offsets — only WHICH positions supply
        the pieces differs.  Lowers to a single full-axis
        ``all_gather`` plus a position-indexed piece table: no
        switches, no permute rounds.  Copies are exact, so the path is
        valid under either reduction mode; sources with overlapping
        boxes are interchangeable because replicated shards are bitwise
        identical (Partial layouts, whose shards are summands, are
        excluded)."""
        groups = [g for step in stage.steps for g in step.groups]
        if not groups or any(g.reduce for g in groups):
            return None
        if len(self.order) != self.n_mesh:
            return None
        nxt = stage.annot_after
        if set(nxt.devices) != set(self.order.devices):
            return None
        if set(prev.devices) != set(self.order.devices):
            return None
        if self._has_partial(prev) or self._has_partial(nxt):
            return None
        pboxes = [prev.device_box(self.order.devices[p], self.shape)
                  for p in range(self.n_mesh)]
        piece_shape = box_shape(pboxes[0])
        if any(box_shape(b) != piece_shape for b in pboxes):
            return None
        if piece_shape != pad_shape(prev, self.shape):
            return None
        next_pad = pad_shape(nxt, self.shape)
        template: list | None = None   # (dst_rel, piece_rel, shape) per tile
        picks: list = [None] * self.n_mesh
        for dev in self.order.devices:
            nbox = nxt.device_box(dev, self.shape)
            if box_shape(nbox) != next_pad:
                return None
            tiles, seen = [], set()
            for p in range(self.n_mesh):
                ib = box_intersect(pboxes[p], nbox)
                if ib is not None and ib not in seen:
                    seen.add(ib)
                    tiles.append(ib)
            tiles.sort(key=lambda b: tuple(lo for lo, _ in b))
            if sum(int(np.prod(box_shape(t))) for t in tiles) != \
                    int(np.prod(next_pad)):
                return None      # tiles must cover the dst box exactly...
            for a in range(len(tiles)):
                for b in range(a + 1, len(tiles)):
                    if box_intersect(tiles[a], tiles[b]) is not None:
                        return None   # ...without overlap
            if template is None:
                template = []
                for t in tiles:
                    p = next((p for p in range(self.n_mesh)
                              if box_contains(pboxes[p], t)), None)
                    if p is None:
                        return None
                    template.append((rel_slices(nbox, t),
                                     rel_slices(pboxes[p], t),
                                     box_shape(t)))
            if len(tiles) != len(template):
                return None
            chosen = []
            for t, (d_r, p_r, ts) in zip(tiles, template):
                if rel_slices(nbox, t) != d_r or box_shape(t) != ts:
                    return None
                p = next((p for p in range(self.n_mesh)
                          if box_contains(pboxes[p], t)
                          and rel_slices(pboxes[p], t) == p_r), None)
                if p is None:
                    return None
                chosen.append(p)
            picks[self.order.pos(dev)] = chosen
        return {"kind": "gather", "piece_shape": piece_shape,
                "k": len(template),
                "dst_rel": [t[0] for t in template],
                "piece_rel": [t[1] for t in template],
                "picks": picks, "next_pad": next_pad}

    def _emit_uniform_ident(self, x, uni, i, out_dtype):
        import jax
        import jax.numpy as jnp

        if all(not any(s) for s in uni["starts"]) and \
                tuple(x.shape) == tuple(uni["out_shape"]):
            return x.astype(out_dtype)      # pure no-op stage
        st = jnp.asarray(uni["starts"], jnp.int32)[i]
        y = jax.lax.dynamic_slice(
            x, tuple(st[d] for d in range(len(uni["out_shape"]))),
            uni["out_shape"])
        return y.astype(out_dtype)

    def _emit_uniform_gather(self, x, uni, i, out_dtype):
        import jax
        import jax.numpy as jnp

        contrib = x[tuple(slice(0, n) for n in uni["piece_shape"])]
        gathered = jax.lax.all_gather(contrib, self.axis)
        picks = jnp.asarray(uni["picks"], jnp.int32)[i]
        arr = jnp.zeros(uni["next_pad"], out_dtype)
        for t in range(uni["k"]):
            piece = gathered[picks[t]]
            arr = arr.at[uni["dst_rel"][t]].set(
                piece[uni["piece_rel"][t]].astype(out_dtype))
        return arr

    def _emit_uniform_stage(self, x, uni, out_dtype, i=None):
        """Straight-line emission of a uniform stage: reduce stages get
        one fused subgroup collective, ident/gather stages a
        position-indexed slice / full-axis gather — never a switch.
        Exact mode folds reduces in float64; for subgroups of <=2
        sources a float64 ``psum`` IS the ordered fold bitwise
        (two-operand IEEE addition is commutative), so the all_gather +
        sequential fold is only kept for larger groups."""
        import jax
        import jax.numpy as jnp

        if uni["kind"] == "ident":
            return self._emit_uniform_ident(x, uni, i, out_dtype)
        if uni["kind"] == "gather":
            return self._emit_uniform_gather(x, uni, i, out_dtype)
        contrib = x[uni["src_rel"]]
        if self.reduction == "fast" or uni["k"] <= 2:
            # exact for k<=2 without float64: the exact sum of two
            # values fits in float64, so the ordered f64 fold cast back
            # to the input dtype is the correctly-rounded two-operand
            # sum — i.e. bitwise the native-dtype psum
            if self.reduction != "fast":
                assert jnp.dtype(out_dtype) == contrib.dtype, \
                    "two-operand psum shortcut needs matching dtypes"
            y = jax.lax.psum(contrib, self.axis,
                             axis_index_groups=uni["groups"])
        else:
            gathered = jax.lax.all_gather(
                contrib.astype(jnp.float64), self.axis,
                axis_index_groups=uni["groups"])
            y = gathered[0]
            for j in range(1, uni["k"]):
                y = y + gathered[j]
        arr = jnp.zeros(uni["next_pad"], out_dtype)
        return arr.at[uni["dst_rel"]].set(
            y[uni["piece_rel"]].astype(out_dtype))

    def _reduce_groups_static(self, g) -> tuple[list | None, list | None]:
        """axis_index_groups partitions for one reduce group: the
        ``(psum_groups, all_gather_groups)`` pair, either of which is
        ``None`` when the masked full-axis collective must be kept.

        The source devices form one subgroup; every other mesh position
        still has to appear (XLA requires a partition of the axis), so
        non-participants ride along as singletons for psum (ragged
        partitions are fine for all-reduce) and as equal-size dummy
        chunks for all_gather (gather output shapes must be uniform —
        when the remainder doesn't chunk evenly the exact path falls
        back to the full axis).  Results on non-source devices are
        garbage, which is only safe because every destination is a
        source; otherwise both stay masked full-axis.
        """
        pos = [self.order.pos(s) for s in g.srcs]  # srcs order == fold order
        if not set(g.dsts) <= set(g.srcs):
            return None, None
        others = [p for p in range(self.n_mesh) if p not in set(pos)]
        psum_groups = [pos] + [[p] for p in others]
        k = len(pos)
        ag_groups = None
        if len(others) % k == 0:
            ag_groups = [pos] + [others[i:i + k]
                                 for i in range(0, len(others), k)]
        return psum_groups, ag_groups

    # -- traced emission ---------------------------------------------------

    def _emit_rounds(self, x, rounds: list[_Round], prev_annot, i):
        """Emit the stage's fused permutes; returns, per copy group, the
        received piece expression valid on each destination device."""
        import jax
        import jax.numpy as jnp

        received: dict[tuple[int, int], object] = {}  # (dst, id(g)) -> arr
        for r in rounds:
            pad = tuple(max(box_shape(g.box)[d] for _, _, g in r.pairs)
                        for d in range(len(self.shape)))
            operand = jnp.zeros(pad, x.dtype)
            for s, _, g in r.pairs:  # each src appears once per round
                sl = rel_slices(prev_annot.device_box(s, self.shape), g.box)
                val = jnp.zeros(pad, x.dtype).at[
                    tuple(slice(0, n) for n in box_shape(g.box))].set(x[sl])
                operand = jnp.where(i == self.order.pos(s), val, operand)
            perm = [(self.order.pos(s), self.order.pos(d))
                    for s, d, _ in r.pairs]
            out = jax.lax.ppermute(operand, self.axis, perm)
            for _, d, g in r.pairs:
                received[(d, id(g))] = out[
                    tuple(slice(0, n) for n in box_shape(g.box))]
        return received

    def _emit_copy_piece(self, x, g, prev_annot, i, received):
        import jax.numpy as jnp

        src = g.srcs[0]
        bshape = box_shape(g.box)
        piece = jnp.zeros(bshape, x.dtype)
        for d in g.dsts:
            if d == src:
                val = x[rel_slices(prev_annot.device_box(src, self.shape),
                                   g.box)]
            else:
                val = received[(d, id(g))]
            piece = jnp.where(i == self.order.pos(d), val, piece)
        return piece

    def _emit_reduce(self, x, g, prev_annot, i):
        import jax
        import jax.numpy as jnp

        # per-source contribution: each source extracts its own slice of
        # the group box (offsets differ per source), everyone else is zero
        branch_of_pos = [0] * self.n_mesh
        extracts = [None]
        for s in g.srcs:
            branch_of_pos[self.order.pos(s)] = len(extracts)
            extracts.append(rel_slices(
                prev_annot.device_box(s, self.shape), g.box))
        gshape = box_shape(g.box)
        branches = [lambda v: jnp.zeros(gshape, v.dtype)]
        for sl in extracts[1:]:
            branches.append(lambda v, sl=sl: v[sl])
        tbl = jnp.asarray(branch_of_pos, jnp.int32)
        contrib = jax.lax.switch(tbl[i], branches, x)
        psum_groups, ag_groups = self._reduce_partitions[id(g)]
        if self.reduction == "fast":
            return jax.lax.psum(contrib, self.axis,
                                axis_index_groups=psum_groups)
        if psum_groups is not None and len(g.srcs) <= 2:
            # native-dtype psum == the ordered f64 fold cast back,
            # bitwise, for <=2 sources (two-operand addition is
            # commutative and its exact sum fits in float64)
            return jax.lax.psum(contrib, self.axis,
                                axis_index_groups=psum_groups)
        if ag_groups is not None:
            # subgroup gather: position j within the group IS g.srcs[j],
            # so the float64 fold keeps the simulator's srcs order
            gathered = jax.lax.all_gather(contrib.astype(jnp.float64),
                                          self.axis,
                                          axis_index_groups=ag_groups)
            acc = gathered[0]
            for j in range(1, len(g.srcs)):
                acc = acc + gathered[j]
            return acc
        gathered = jax.lax.all_gather(contrib.astype(jnp.float64), self.axis)
        acc = gathered[self.order.pos(g.srcs[0])]
        for s in g.srcs[1:]:
            acc = acc + gathered[self.order.pos(s)]
        return acc

    def _stage_update(self, x, pieces, prev_annot, next_annot, i, out_dtype):
        import jax
        import jax.numpy as jnp

        next_pad = pad_shape(next_annot, self.shape)

        def branch_for(pos):
            if pos >= len(self.order) or \
                    self.order.devices[pos] not in next_annot.devices:
                return lambda v: jnp.zeros(next_pad, out_dtype)
            dev = self.order.devices[pos]
            nbox = next_annot.device_box(dev, self.shape)

            def build(v):
                arr = jnp.zeros(next_pad, out_dtype)
                if dev in prev_annot.devices:
                    pbox = prev_annot.device_box(dev, self.shape)
                    inter = box_intersect(pbox, nbox)
                    if inter is not None:
                        arr = arr.at[rel_slices(nbox, inter)].set(
                            v[rel_slices(pbox, inter)].astype(out_dtype))
                for dbox, piece, dsts in pieces:
                    if dev not in dsts:
                        continue
                    inter = box_intersect(dbox, nbox)
                    if inter is None:
                        continue
                    arr = arr.at[rel_slices(nbox, inter)].set(
                        piece[rel_slices(dbox, inter)].astype(out_dtype))
                return arr

            return build

        return jax.lax.switch(i, [branch_for(p) for p in range(self.n_mesh)],
                              x)

    def apply(self, x, i, out_dtype=None):
        """Run the plan's stages on local padded value ``x`` (this device's
        shard at the origin); ``i`` is the traced mesh axis index."""
        out_dtype = out_dtype or x.dtype
        prev_annot = self.plan.src
        for stage, rounds, uni in zip(self.plan.stages, self._stage_rounds,
                                      self._uniform_stages):
            if uni is not None:
                x = self._emit_uniform_stage(x, uni, out_dtype, i)
                prev_annot = stage.annot_after
                continue
            received = self._emit_rounds(x, rounds, prev_annot, i)
            pieces = []
            for step in stage.steps:
                for g in step.groups:
                    if g.reduce:
                        piece = self._emit_reduce(x, g, prev_annot, i)
                    else:
                        piece = self._emit_copy_piece(x, g, prev_annot, i,
                                                      received)
                    pieces.append((g.box, piece, g.dsts))
            x = self._stage_update(x, pieces, prev_annot, stage.annot_after,
                                   i, out_dtype)
            prev_annot = stage.annot_after
        return x


def maybe_x64(fn, needs_x64: bool):
    """Wrap ``fn`` in a thread-local x64 scope when the exact float64 fold
    is traced (keyed into the jit cache; process defaults untouched)."""
    if not needs_x64:
        return fn
    from jax.experimental import enable_x64

    def run_x64(*args):
        with enable_x64():
            return fn(*args)

    return run_x64


def lower_plan(plan: CommPlan, shape: tuple[int, ...], mesh,
               order: DeviceOrder | None = None, *,
               reduction: str = "exact", dtype=None,
               stats_out: LoweringStats | None = None,
               fuse_permutes: bool = True):
    """Compile ``plan`` into a jitted ``f(stacked) -> stacked`` over ``mesh``.

    ``stacked`` has shape ``(mesh_size, *pad_shape(plan.src))``: row
    ``order.pos(dev)`` holds device ``dev``'s (zero-padded) local shard.
    The result is stacked the same way under the final stage annotation.
    ``fuse_permutes=False`` lowers copies GSPMD-resharding style — one
    ppermute per (src, dst) pair, uniform fast paths off — the baseline
    the batched-permute fusion micro-benchmark measures against.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    order = order or DeviceOrder.for_plan(plan)
    axis = mesh.axis_names[0]
    n_mesh = int(mesh.devices.size)
    lowering = PlanLowering(plan, shape, order, axis, n_mesh,
                            reduction=reduction,
                            fuse_permutes=fuse_permutes)
    if stats_out is not None:
        stats_out.merge(lowering.stats)

    def body(block):
        x = block[0]
        i = jax.lax.axis_index(axis)
        return lowering.apply(x, i, dtype or x.dtype)[None]

    rank = len(shape)
    spec = P(axis, *([None] * rank))
    jitted = jax.jit(shard_map(body, mesh=mesh, in_specs=spec,
                               out_specs=spec, check_rep=False))
    return maybe_x64(jitted, lowering.needs_x64 and reduction == "exact")
