"""Lower a :class:`~repro.core.plan.CommPlan` onto real JAX devices.

The simulator executes plans on a ``dict[device, np.ndarray]``; this module
compiles the *same* stage semantics into one ``jax.shard_map`` program over
a 1-D device mesh, so every resolved communication operator actually moves
tensors through XLA collectives:

* copy groups (SR / AG / SplitAG / BSR) — one ``jax.lax.ppermute`` per
  (src, dst) pair (XLA collective-permute; ppermute forbids duplicated
  sources, so a multicast group is emitted as a pair per receiver),
* reduce groups (AR / RS / SplitAR / SplitRS) —
  - ``reduction="exact"``: ``jax.lax.all_gather`` of the masked per-source
    contributions, then a left fold in float64 following the group's
    ``srcs`` order.  This reproduces ``simulator.apply_plan`` **bit
    exactly** for arbitrary inputs (the simulator accumulates in float64
    in the same order before casting back),
  - ``reduction="fast"``: a single masked ``jax.lax.psum`` in the native
    dtype (a real all-reduce; bit-exact only when the data makes the sum
    order-insensitive, e.g. integer-valued shards),
* ID / Slice — no collective; covered by the local-retention path.

Per-device specialization (paper §5.3) is realized literally: the stage
state update is a ``jax.lax.switch`` over ``axis_index`` whose branches are
the per-device programs — each branch only writes the slice-group
deliveries that device participates in, mirroring
:func:`repro.core.specialize.specialize`.

Because every device can hold a differently-shaped box (heterogeneous
``hsplits``), local shards are padded to the per-stage elementwise-max box
shape; geometry is static, so stage coverage is checked at lowering time
with the same strictness as the simulator.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.annotations import HSPMD
from repro.core.plan import (Box, CommPlan, box_contains, box_intersect,
                             box_shape, rel_slices)

REDUCTIONS = ("exact", "fast")


@dataclass(frozen=True)
class DeviceOrder:
    """Mapping between logical HSPMD device ids and mesh axis positions."""

    devices: tuple[int, ...]

    @classmethod
    def for_plan(cls, plan: CommPlan) -> "DeviceOrder":
        devs = set()
        if plan.src is not None:
            devs |= set(plan.src.devices)
        for annot in plan.annots:
            devs |= set(annot.devices)
        for step in plan.steps:
            for g in step.groups:
                devs |= set(g.srcs) | set(g.dsts)
        return cls(tuple(sorted(devs)))

    def pos(self, dev: int) -> int:
        return self.devices.index(dev)

    def __len__(self) -> int:
        return len(self.devices)


def pad_shape(annot: HSPMD, shape: tuple[int, ...]) -> tuple[int, ...]:
    """Elementwise max of the per-device box shapes (uniform local buffer)."""
    dims = [1] * len(shape)
    for dev in annot.devices:
        for d, s in enumerate(annot.device_shape(dev, shape)):
            dims[d] = max(dims[d], s)
    return tuple(dims)


def check_stage_coverage(prev: HSPMD, nxt: HSPMD,
                         deliveries: list[tuple[Box, tuple[int, ...]]],
                         shape: tuple[int, ...], kinds: str) -> None:
    """Static replica of the simulator's strict coverage assertion."""
    for dev in nxt.devices:
        box = nxt.device_box(dev, shape)
        covered = np.zeros(box_shape(box), dtype=bool)
        if dev in prev.devices:
            inter = box_intersect(prev.device_box(dev, shape), box)
            if inter is not None:
                covered[rel_slices(box, inter)] = True
        for dbox, dsts in deliveries:
            if dev not in dsts:
                continue
            inter = box_intersect(dbox, box)
            if inter is not None:
                covered[rel_slices(box, inter)] = True
        if not covered.all():
            raise AssertionError(
                f"dev {dev}: {int((~covered).sum())} uncovered elements "
                f"after stage [{kinds}]")


def lower_plan(plan: CommPlan, shape: tuple[int, ...], mesh,
               order: DeviceOrder | None = None, *,
               reduction: str = "exact", dtype=None):
    """Compile ``plan`` into a jitted ``f(stacked) -> stacked`` over ``mesh``.

    ``stacked`` has shape ``(mesh_size, *pad_shape(plan.src))``: row
    ``order.pos(dev)`` holds device ``dev``'s (zero-padded) local shard.
    The result is stacked the same way under the final stage annotation.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if reduction not in REDUCTIONS:
        raise ValueError(f"reduction must be one of {REDUCTIONS}")
    if plan.src is None:
        raise ValueError("plan has no source annotation")
    order = order or DeviceOrder.for_plan(plan)
    axis = mesh.axis_names[0]
    n_mesh = int(mesh.devices.size)
    if n_mesh < len(order):
        raise ValueError(
            f"plan spans {len(order)} logical devices but mesh has only "
            f"{n_mesh}; force more host devices (e.g. "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{len(order)})")

    has_reduce = any(g.reduce for s in plan.steps for g in s.groups)

    # static geometry per stage, verified up front
    prev = plan.src
    for stage in plan.stages:
        deliveries = [(g.box, g.dsts) for step in stage.steps
                      for g in step.groups]
        for step in stage.steps:
            for g in step.groups:
                for s in g.srcs:
                    sbox = prev.device_box(s, shape)
                    if not box_contains(sbox, g.box):
                        raise AssertionError(
                            f"src dev {s} box {sbox} does not contain "
                            f"group box {g.box}")
        kinds = "+".join(st.kind for st in stage.steps)
        check_stage_coverage(prev, stage.annot_after, deliveries, shape,
                             kinds)
        prev = stage.annot_after

    def _emit_copy(x, g, prev_annot, i):
        src = g.srcs[0]
        src_pos = order.pos(src)
        sl = rel_slices(prev_annot.device_box(src, shape), g.box)
        operand = jnp.where(i == src_pos, x[sl], jnp.zeros_like(x[sl]))
        received = jnp.zeros_like(operand)
        for d in g.dsts:
            if d == src:
                continue
            received = received + jax.lax.ppermute(
                operand, axis, [(src_pos, order.pos(d))])
        return jnp.where(i == src_pos, operand, received)

    def _emit_reduce(x, g, prev_annot, i):
        # per-source contribution: each source extracts its own slice of
        # the group box (offsets differ per source), everyone else is zero
        branch_of_pos = [0] * n_mesh
        extracts = [None]
        for s in g.srcs:
            branch_of_pos[order.pos(s)] = len(extracts)
            extracts.append(rel_slices(prev_annot.device_box(s, shape),
                                       g.box))
        gshape = box_shape(g.box)
        branches = [lambda v: jnp.zeros(gshape, v.dtype)]
        for sl in extracts[1:]:
            branches.append(lambda v, sl=sl: v[sl])
        tbl = jnp.asarray(branch_of_pos, jnp.int32)
        contrib = jax.lax.switch(tbl[i], branches, x)
        if reduction == "fast":
            return jax.lax.psum(contrib, axis)
        gathered = jax.lax.all_gather(contrib.astype(jnp.float64), axis)
        acc = gathered[order.pos(g.srcs[0])]
        for s in g.srcs[1:]:
            acc = acc + gathered[order.pos(s)]
        return acc

    def _stage_update(x, pieces, prev_annot, next_annot, i, out_dtype):
        next_pad = pad_shape(next_annot, shape)

        def branch_for(pos):
            if pos >= len(order) or \
                    order.devices[pos] not in next_annot.devices:
                return lambda v: jnp.zeros(next_pad, out_dtype)
            dev = order.devices[pos]
            nbox = next_annot.device_box(dev, shape)

            def build(v):
                arr = jnp.zeros(next_pad, out_dtype)
                if dev in prev_annot.devices:
                    pbox = prev_annot.device_box(dev, shape)
                    inter = box_intersect(pbox, nbox)
                    if inter is not None:
                        arr = arr.at[rel_slices(nbox, inter)].set(
                            v[rel_slices(pbox, inter)].astype(out_dtype))
                for dbox, piece, dsts in pieces:
                    if dev not in dsts:
                        continue
                    inter = box_intersect(dbox, nbox)
                    if inter is None:
                        continue
                    arr = arr.at[rel_slices(nbox, inter)].set(
                        piece[rel_slices(dbox, inter)].astype(out_dtype))
                return arr

            return build

        return jax.lax.switch(i, [branch_for(p) for p in range(n_mesh)], x)

    def body(block):
        x = block[0]
        out_dtype = dtype or x.dtype
        i = jax.lax.axis_index(axis)
        prev_annot = plan.src
        for stage in plan.stages:
            pieces = []
            for step in stage.steps:
                for g in step.groups:
                    emit = _emit_reduce if g.reduce else _emit_copy
                    pieces.append((g.box, emit(x, g, prev_annot, i), g.dsts))
            x = _stage_update(x, pieces, prev_annot, stage.annot_after, i,
                              out_dtype)
            prev_annot = stage.annot_after
        return x[None]

    rank = len(shape)
    spec = P(axis, *([None] * rank))
    jitted = jax.jit(shard_map(body, mesh=mesh, in_specs=spec,
                               out_specs=spec, check_rep=False))
    if has_reduce and reduction == "exact":
        # the exact fold traces in float64; scope x64 to this program
        # (thread-local, keyed into the jit cache) instead of flipping
        # the process-global default dtypes
        from jax.experimental import enable_x64

        def run_x64(stacked):
            with enable_x64():
                return jitted(stacked)

        return run_x64
    return jitted
