"""RG-LRU linear-recurrence Pallas TPU kernel.

The gated linear recurrence ``h_t = a_t h_{t-1} + b_t`` is memory-bound;
the TPU-native layout is:
  * Grid ``(batch, width_blocks, num_chunks)`` — chunks sequential
    (``arbitrary``) carrying the hidden state in a (1, block_w) fp32 VMEM
    scratch; batch and width are embarrassingly parallel (the recurrence
    couples only the time dimension).
  * Within a chunk the recurrence is unrolled with ``fori_loop`` over
    rows of the (chunk, block_w) VMEM tile — sublane-major traversal, so
    each step is a fused multiply-add over one 8x128-aligned row.
  * a_t and b_t are precomputed elementwise by the wrapper
    (``a = exp(-c softplus(lam) r)``, ``b = sqrt(1-a^2) (i * x)``), keeping
    the kernel a pure scan.

Oracle: :func:`repro.kernels.ref.rglru_ref` (associative-scan formulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

_C = 8.0


def _kernel(a_ref, b_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (chunk, w)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h = carry                              # (1, w)
        h = a[t][None, :] * h + b[t][None, :]
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_pallas(x, r, i, lam, *, chunk: int = 128, block_w: int = 128,
                 interpret: bool = False):
    """RG-LRU scan.  x, r, i: (b, s, w); lam: (w,).  Returns h: (b, s, w)."""
    b, s, w = x.shape
    assert s % chunk == 0, (s, chunk)
    block_w = min(block_w, w)
    assert w % block_w == 0, (w, block_w)
    nc = s // chunk

    log_a = -_C * jax.nn.softplus(lam)[None, None, :] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * x).astype(jnp.float32)

    grid = (b, w // block_w, nc)
    kern = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_w),
                         lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, chunk, block_w),
                         lambda bi, wi, ci: (bi, ci, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((b, s, w), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(a, bterm)
    return y
