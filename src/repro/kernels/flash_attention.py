"""Flash attention Pallas TPU kernel (GQA-aware, causal / sliding-window).

TPU-native design (not a CUDA port):
  * Grid ``(batch, q_heads, num_q_blocks, num_k_blocks)`` with the k-block
    dimension marked ``arbitrary`` (sequential) so the online-softmax
    accumulators live in VMEM scratch across k iterations.
  * BlockSpecs tile Q/K/V into (block_q, head_dim) / (block_k, head_dim)
    VMEM windows; head_dim and block sizes are MXU-aligned (128 multiples).
  * GQA is expressed in the K/V index maps (q-head h reads kv-head
    ``h // (H // K)``) — no materialized ``jnp.repeat`` over heads, which
    would multiply HBM traffic by H/K.
  * Causal + window masks are applied with 2D iota inside the kernel;
    fully-masked k blocks are skipped by the index-map-level early loop
    bound (conservative: we rely on @pl.when zero-cost masking here).

Numerics follow the standard streaming softmax: running row max ``m``,
normalizer ``l`` and accumulator ``acc`` in fp32 scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int | None, block_q: int, block_k: int,
            num_kb: int, sm_scale: float):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                  # (bq, bk)

    q_ids = qb * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_ids = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, LANES)
    m_cur = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev - m_new)                   # (bq, LANES)
    p = jnp.exp(s - m_new[:, :1])                     # (bq, bk)
    l_new = l_scr[...] * alpha \
        + jnp.broadcast_to(jnp.sum(p, axis=1, keepdims=True),
                           m_prev.shape)
    acc = acc_scr[...] * alpha[:, :1] \
        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kb == num_kb - 1)
    def _finish():
        l = l_scr[...][:, :1]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, K, Sk, D), H % K == 0. Returns (B,H,Sq,D).

    On CPU pass ``interpret=True`` (the validation mode); on TPU the same
    call compiles to a fused VMEM-tiled kernel.
    """
    b, h, sq, d = q.shape
    kh, sk = k.shape[1], k.shape[2]
    assert h % kh == 0, (h, kh)
    rep = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    num_qb, num_kb = sq // block_q, sk // block_k
    sm_scale = 1.0 / (d ** 0.5)

    grid = (b, h, num_qb, num_kb)
    kern = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_kb=num_kb, sm_scale=sm_scale)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, rep=rep: (bi, hi // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max m
            pltpu.VMEM((block_q, LANES), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, d), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
