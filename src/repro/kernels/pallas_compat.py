"""Pallas API drift shim.

jax renamed the Mosaic TPU compiler-params class across releases:
``pltpu.TPUCompilerParams`` (jax <= 0.4.x) became ``pltpu.CompilerParams``
(newer).  All kernels go through :func:`tpu_compiler_params` so one
``getattr`` check absorbs the drift.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct the TPU compiler params object under either jax API."""
    return _CompilerParams(**kwargs)
