"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU-native layout decisions (vs the paper's CUDA kernel):
  * Grid ``(batch, heads, num_chunks)`` — chunks are ``arbitrary``
    (sequential) so the inter-chunk SSM state (head_dim x d_state, fp32)
    persists in VMEM scratch; batch/head dims are parallel.
  * Per-chunk work is three MXU matmuls: the intra-chunk quadratic
    (C_c B_c^T ⊙ L) x̄, the state read-out C_c S^T, and the state update
    x̄^T (B_c ⊙ decay) — all with chunk and d_state padded to 128 lanes.
  * The decay factors are computed from ``la = dt * A`` which the wrapper
    precomputes elementwise (keeps A out of SMEM scalar plumbing).

The oracle is :func:`repro.kernels.ref.ssd_scan_ref` (the model's own
pure-jnp chunked scan, itself validated against step-by-step decode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import tpu_compiler_params


def _kernel(la_ref, xbar_ref, b_ref, c_ref, y_ref, state_out_ref, state_scr,
            *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    la = la_ref[0, :, 0].astype(jnp.float32).reshape(chunk, 1)   # (q,1)
    xbar = xbar_ref[0, :, 0].astype(jnp.float32)                 # (q,p)
    B = b_ref[0].astype(jnp.float32)                             # (q,n)
    C = c_ref[0].astype(jnp.float32)                             # (q,n)

    cum = jnp.cumsum(la, axis=0)                                 # (q,1)
    total = cum[chunk - 1, 0]

    # intra-chunk: (C_i . B_j) * exp(cum_i - cum_j) for i >= j
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = cum - cum.reshape(1, chunk)                           # (q,q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(qi >= kj, jnp.exp(diff), 0.0)
    y_intra = jax.lax.dot_general(scores * L, xbar,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # carried-state contribution: exp(cum_i) * (C_i . S)
    state = state_scr[...]                                       # (p,n)
    y_inter = jnp.exp(cum) * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S' = S * exp(total) + x̄^T (B ⊙ exp(total - cum))
    decay_to_end = jnp.exp(total - cum)                          # (q,1)
    state_new = state * jnp.exp(total) + jax.lax.dot_general(
        xbar, B * decay_to_end, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = state_new

    @pl.when(ci == num_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_new.astype(state_out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.  Same contract as the oracle:
    x: (b,s,h,p); dt: (b,s,h) (softplus-ed); A: (h,); B/C: (b,s,n).
    Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    la = (dt * A[None, None, :]).astype(jnp.float32)      # (b,s,h)
    xbar = x * dt[..., None].astype(x.dtype)

    grid = (b, h, nc)
    kern = functools.partial(_kernel, chunk=chunk, num_chunks=nc)
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(la, xbar, B, C)
    return y, state
