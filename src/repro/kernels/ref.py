"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rglru import rglru_scan as _rglru_assoc
from repro.models.ssm import ssd_chunked as _ssd_chunked


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, K, Sk, D) with H % K == 0 (GQA)."""
    b, h, sq, d = q.shape
    kh = k.shape[1]
    rep = h // kh
    kq = jnp.repeat(k, rep, axis=1)
    vq = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kq).astype(jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    sk = k.shape[2]
    qi = jnp.arange(sq)
    ki = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= ki[None, :] <= qi[:, None]
    if window is not None:
        mask &= ki[None, :] > qi[:, None] - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, vq)


def ssd_scan_ref(x, dt, A, B, C, chunk: int):
    """Chunked SSD oracle (the model's own jnp implementation).
    x: (b,s,h,p), dt: (b,s,h), A: (h,), B/C: (b,s,n)."""
    y, state = _ssd_chunked(x, dt, A, B, C, chunk)
    return y, state


def rglru_ref(x, r, i, lam):
    """Associative-scan RG-LRU oracle. x,r,i: (b,s,w); lam: (w,)."""
    return _rglru_assoc(x, r, i, lam)
