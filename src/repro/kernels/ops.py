"""Jit'd dispatch wrappers for the Pallas kernels.

``use_kernel`` policy:
  * ``"auto"``   — Pallas on TPU backends, XLA reference elsewhere
                   (this CPU container always takes the reference path
                   outside of interpret-mode tests);
  * ``"pallas"`` — force the kernel (pass ``interpret=True`` on CPU);
  * ``"ref"``    — force the pure-jnp oracle.

The model layers call these wrappers, so flipping one config flag moves
every hot spot onto the TPU kernels without touching model code.
"""

from __future__ import annotations

import jax

from . import ref as _ref
from .flash_attention import flash_attention as _flash_pallas
from .rglru_scan import rglru_pallas as _rglru_pallas
from .ssd_scan import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, use_kernel="auto",
              interpret=False):
    """q: (B,H,Sq,D); k,v: (B,K,Sk,D)."""
    if use_kernel == "pallas" or (use_kernel == "auto" and _on_tpu()):
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=interpret or not _on_tpu())
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd(x, dt, A, B, C, *, chunk=128, use_kernel="auto", interpret=False):
    if use_kernel == "pallas" or (use_kernel == "auto" and _on_tpu()):
        return _ssd_pallas(x, dt, A, B, C, chunk=chunk,
                           interpret=interpret or not _on_tpu())
    return _ref.ssd_scan_ref(x, dt, A, B, C, chunk)


def rglru(x, r, i, lam, *, chunk=128, use_kernel="auto", interpret=False):
    if use_kernel == "pallas" or (use_kernel == "auto" and _on_tpu()):
        return _rglru_pallas(x, r, i, lam, chunk=chunk,
                             interpret=interpret or not _on_tpu())
    return _ref.rglru_ref(x, r, i, lam)
