"""Global kernel dispatch policy.

``set_policy("pallas")`` flips every hot spot (attention, SSD scan,
RG-LRU scan) in the model layers onto the Pallas TPU kernels;
``"ref"`` forces the pure-XLA path (the default on CPU, and the path
the multi-pod dry-run lowers — Mosaic kernels target real TPUs).

The graph-IR runtime consumes the same policy through
:func:`select_attention_impl_per_class`: when ``runtime.program`` lowers
an ``attention`` op it asks this module — with the device-LOCAL shard
shapes — whether the Pallas flash kernel applies
(``kernels.flash_attention``) or the pure-XLA reference must run
(``kernels.ref.flash_attention_ref``).  The decision is memoized per
distinct (q, kv) shard-shape pair, so every device of a specialization
class (``core.lowered_ir``) shares ONE decision and ONE emitted branch;
it participates in the class partition (same shapes, different impl ⇒
different classes — can't happen under one policy, but the seam is
explicit).  The decision is static per compiled program and is tallied
per emitted class in ``LoweringStats``.
"""

from __future__ import annotations

VALID_POLICIES = ("auto", "pallas", "ref")

_POLICY = "auto"


def set_policy(policy: str) -> None:
    if policy not in VALID_POLICIES:
        raise ValueError(
            f"unknown kernel policy {policy!r}; valid policies: "
            f"{', '.join(VALID_POLICIES)}")
    global _POLICY
    _POLICY = policy
    _impl_cache.clear()


def get_policy() -> str:
    return _POLICY


def use_pallas() -> bool:
    import jax
    if _POLICY == "pallas":
        return True
    if _POLICY == "ref":
        return False
    return jax.default_backend() == "tpu"


def attention_eligible(q_shape, kv_shape, *, block_q: int = 128,
                       block_k: int = 128) -> bool:
    """Whether the Pallas flash-attention kernel can take these
    device-local shards: ``q (B, H, Sq, D)``, ``k/v (B, K, Sk, D)``.
    Mirrors the kernel's own constraints (GQA head ratio, sequence
    lengths tiled by the block sizes, lane-aligned head dim)."""
    if len(q_shape) != 4 or len(kv_shape) != 4:
        return False
    _, h, sq, d = q_shape
    _, kh, sk, kd = kv_shape
    bq, bk = min(block_q, sq), min(block_k, sk)
    return (kh >= 1 and h % kh == 0 and d == kd and d % 8 == 0
            and sq % bq == 0 and sk % bk == 0)


def select_attention_impl(q_shape, kv_shape) -> str:
    """``"pallas"`` or ``"ref"`` for one device-local attention dispatch
    (the graph-IR lowering seam; see ``runtime.program``)."""
    if use_pallas() and attention_eligible(q_shape, kv_shape):
        return "pallas"
    return "ref"


#: (q_shape, kv_shape) -> impl; cleared on set_policy so a policy flip
#: re-decides every class
_impl_cache: dict[tuple, str] = {}


def select_attention_impl_per_class(q_shape, kv_shape) -> str:
    """Per-class dispatch: memoized :func:`select_attention_impl` over
    distinct device-local (q, kv) shard-shape pairs, so all devices of a
    specialization class resolve to the same kernel with one decision."""
    key = (tuple(q_shape), tuple(kv_shape))
    impl = _impl_cache.get(key)
    if impl is None:
        impl = _impl_cache[key] = select_attention_impl(*key)
    return impl
