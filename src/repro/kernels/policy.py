"""Global kernel dispatch policy.

``set_policy("pallas")`` flips every hot spot (attention, SSD scan,
RG-LRU scan) in the model layers onto the Pallas TPU kernels;
``"ref"`` forces the pure-XLA path (the default on CPU, and the path
the multi-pod dry-run lowers — Mosaic kernels target real TPUs).

The graph-IR runtime consumes the same policy through
:func:`select_attention_impl`: when ``runtime.program`` lowers an
``attention`` ExecItem it asks this module — per device, with the
device-LOCAL shard shapes — whether the Pallas flash kernel applies
(``kernels.flash_attention``) or the pure-XLA reference must run
(``kernels.ref.flash_attention_ref``).  The decision is static per
compiled program and is tallied in ``LoweringStats``.
"""

from __future__ import annotations

VALID_POLICIES = ("auto", "pallas", "ref")

_POLICY = "auto"


def set_policy(policy: str) -> None:
    if policy not in VALID_POLICIES:
        raise ValueError(
            f"unknown kernel policy {policy!r}; valid policies: "
            f"{', '.join(VALID_POLICIES)}")
    global _POLICY
    _POLICY = policy


def get_policy() -> str:
    return _POLICY


def use_pallas() -> bool:
    import jax
    if _POLICY == "pallas":
        return True
    if _POLICY == "ref":
        return False
    return jax.default_backend() == "tpu"


def attention_eligible(q_shape, kv_shape, *, block_q: int = 128,
                       block_k: int = 128) -> bool:
    """Whether the Pallas flash-attention kernel can take these
    device-local shards: ``q (B, H, Sq, D)``, ``k/v (B, K, Sk, D)``.
    Mirrors the kernel's own constraints (GQA head ratio, sequence
    lengths tiled by the block sizes, lane-aligned head dim)."""
    if len(q_shape) != 4 or len(kv_shape) != 4:
        return False
    _, h, sq, d = q_shape
    _, kh, sk, kd = kv_shape
    bq, bk = min(block_q, sq), min(block_k, sk)
    return (kh >= 1 and h % kh == 0 and d == kd and d % 8 == 0
            and sq % bq == 0 and sk % bk == 0)


def select_attention_impl(q_shape, kv_shape) -> str:
    """``"pallas"`` or ``"ref"`` for one device-local attention dispatch
    (the graph-IR lowering seam; see ``runtime.program``)."""
    if use_pallas() and attention_eligible(q_shape, kv_shape):
        return "pallas"
    return "ref"
