"""Global kernel dispatch policy.

``set_policy("pallas")`` flips every hot spot (attention, SSD scan,
RG-LRU scan) in the model layers onto the Pallas TPU kernels;
``"ref"`` forces the pure-XLA path (the default on CPU, and the path
the multi-pod dry-run lowers — Mosaic kernels target real TPUs).
"""

from __future__ import annotations

_POLICY = "auto"


def set_policy(policy: str) -> None:
    global _POLICY
    assert policy in ("auto", "pallas", "ref")
    _POLICY = policy


def get_policy() -> str:
    return _POLICY


def use_pallas() -> bool:
    import jax
    if _POLICY == "pallas":
        return True
    if _POLICY == "ref":
        return False
    return jax.default_backend() == "tpu"
