"""Mixed-length training policies compared (paper §7.3, Figs 15-16).

Runs the baseline / HotSPa(Hetu-A) / Hetu-B policies over the same
synthetic CommonCrawl-like token stream and prints the per-step time
distribution + the Fig 16-style strategy trace for Hetu-B.  The two
Hetu-B strategies are also exported through ``repro.api`` to price the
regime-change switch the trace pays.

    PYTHONPATH=src python examples/mixed_length.py
"""

import numpy as np

from repro import api
from repro.core.costmodel import LLAMA_32B
from repro.core.topology import NvlinkIbTopology
from repro.scenarios.hetero import layer_weight_shapes, to_api_strategy
from repro.scenarios.mixed_length import (hetu_b_strategy_long,
                                          hetu_b_strategy_short,
                                          run_mixed_length)

N_STEPS = 30

print(f"{'policy':10s} {'mean':>8s} {'p50':>8s} {'p95':>8s} {'switches':>9s}")
traces = {}
for policy in ("baseline", "hotspa", "hetu_b"):
    reps = run_mixed_length(policy, n_steps=N_STEPS, seed=7)
    times = np.array([r.seconds for r in reps])
    traces[policy] = reps
    print(f"{policy:10s} {times.mean():8.2f} {np.percentile(times, 50):8.2f} "
          f"{np.percentile(times, 95):8.2f} "
          f"{sum(r.switched for r in reps):9d}")

print("\nHetu-B per-step trace (paper Fig 16):")
for r in traces["hetu_b"][:20]:
    strat = "S1(long)" if r.max_len > 16384 else "S2(short)"
    mark = f"  <- switch ({r.switch_s * 1e3:.0f} ms)" if r.switched else ""
    print(f"  step {r.step:3d} maxlen {r.max_len:6d} {strat:9s} "
          f"{r.seconds:6.2f}s{mark}")

base = np.mean([r.seconds for r in traces["baseline"]])
hb = np.mean([r.seconds for r in traces["hetu_b"]])
print(f"\nHetu-B speedup over fixed-strategy baseline: {base / hb:.2f}x")

# the S1 <-> S2 regime switch as repro.api strategies (what each "<- switch"
# marker above pays, priced by the fused-BSR planner)
model = LLAMA_32B
shapes = layer_weight_shapes(model)
s_long = to_api_strategy("S1-long", hetu_b_strategy_long(model), model)
s_short = to_api_strategy("S2-short", hetu_b_strategy_short(model), model)
api.Program(api.weights_graph(shapes), [s_long, s_short])  # validates
report = api.estimate_switch(
    [(n, s_long.annots[n], s_short.annots[n], shapes[n], 2)
     for n in shapes], NvlinkIbTopology(gpus_per_node=8, nvlink_gbps=900.0))
print(f"S1 -> S2 switch cost (fused BSR): {report.summary()}")
