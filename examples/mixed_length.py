"""Mixed-length training policies compared (paper §7.3, Figs 15-16).

Runs the baseline / HotSPa(Hetu-A) / Hetu-B policies over the same
synthetic CommonCrawl-like token stream and prints the per-step time
distribution + the Fig 16-style strategy trace for Hetu-B.

    PYTHONPATH=src python examples/mixed_length.py
"""

import numpy as np

from repro.scenarios.mixed_length import run_mixed_length

N_STEPS = 30

print(f"{'policy':10s} {'mean':>8s} {'p50':>8s} {'p95':>8s} {'switches':>9s}")
traces = {}
for policy in ("baseline", "hotspa", "hetu_b"):
    reps = run_mixed_length(policy, n_steps=N_STEPS, seed=7)
    times = np.array([r.seconds for r in reps])
    traces[policy] = reps
    print(f"{policy:10s} {times.mean():8.2f} {np.percentile(times, 50):8.2f} "
          f"{np.percentile(times, 95):8.2f} "
          f"{sum(r.switched for r in reps):9d}")

print("\nHetu-B per-step trace (paper Fig 16):")
for r in traces["hetu_b"][:20]:
    strat = "S1(long)" if r.max_len > 16384 else "S2(short)"
    mark = f"  <- switch ({r.switch_s * 1e3:.0f} ms)" if r.switched else ""
    print(f"  step {r.step:3d} maxlen {r.max_len:6d} {strat:9s} "
          f"{r.seconds:6.2f}s{mark}")

base = np.mean([r.seconds for r in traces["baseline"]])
hb = np.mean([r.seconds for r in traces["hetu_b"]])
print(f"\nHetu-B speedup over fixed-strategy baseline: {base / hb:.2f}x")
