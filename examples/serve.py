"""Serving example: batched prefill + decode with KV/SSM caches.

Generates continuations for a batch of prompts with a reduced model —
exercising the same serve_step the decode dry-run shapes lower — and
shows the serving-time weight placement as a compiled ``repro.api``
strategy (TP column-split projections, the §7 serving layout).

    PYTHONPATH=src python examples/serve.py [--arch mamba2-370m]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import get_config
from repro.models.model import (_run_encoder, decode_step, forward,
                                init_decode_state, init_params)

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-1.5b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=32)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()

# --- serving-time weight placement as a compiled api strategy ----------------
# TP4 serving replicas: projections column-split over a 4-device group,
# switchable to a TP2x2 layout when half the serving pod is drained.
proj_shapes = {"wq": (cfg.d_model, cfg.d_model),
               "wo": (cfg.d_model, cfg.d_model)}
tp4 = api.Strategy("serve-tp4", {
    n: api.spmd([0, 1, 2, 3], api.DS({1: 4})) for n in proj_shapes})
tp2 = api.Strategy("serve-tp2", {
    n: api.spmd([0, 1], api.DS({1: 2})) for n in proj_shapes})
serve_prog = api.Program(api.weights_graph(proj_shapes), [tp4, tp2])
compiled = serve_prog.compile("serve-tp4")
drain = api.estimate_switch(
    [(n, tp4.annots[n], tp2.annots[n], proj_shapes[n], 2)
     for n in proj_shapes])
print(f"serving placement: {compiled.strategy.name} over "
      f"{len(compiled.devices)} devices; drain to tp2 = {drain.summary()}")

params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
B, P = args.batch, args.prompt_len
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (B, P)), jnp.int32)

state = init_decode_state(cfg, B, max_len=P + args.gen)
step = jax.jit(lambda p, s, b: decode_step(p, s, b, cfg))

# prefill by teacher-forcing the prompt through decode steps
t0 = time.time()
for t in range(P):
    logits, state = step(params, state, {"tokens": prompts[:, t:t + 1]})
t_prefill = time.time() - t0

# greedy decode
tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
out = [tok]
t0 = time.time()
for _ in range(args.gen - 1):
    logits, state = step(params, state, {"tokens": tok})
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out.append(tok)
t_dec = time.time() - t0

gen = jnp.concatenate(out, 1)
print(f"arch={cfg.name} batch={B}")
print(f"prefill {P} tokens: {t_prefill:.2f}s; "
      f"decode {args.gen} tokens: {t_dec:.2f}s "
      f"({B * args.gen / max(t_dec, 1e-9):.1f} tok/s)")
print("sample generation (token ids):", np.asarray(gen[0][:16]))
