"""Heterogeneous-cluster training comparison (paper §7.1, Fig 13).

Compares the best *uniform* strategy (what DeepSpeed/Megatron can express)
against Hetu's heterogeneous strategies (paper Appendix A.2, Table 5) on
the paper's H800+H20 clusters, using the calibrated cost model.

    PYTHONPATH=src python examples/hetero_cluster.py
"""

from repro.core.costmodel import (LLAMA_32B, LLAMA_70B, best_uniform,
                                  paper_cluster, step_time)
from repro.scenarios.hetero import HETU_STRATEGIES

CASES = [
    ("32B, 16 H800 + 16 H20", LLAMA_32B, 16, 16, 64),
    ("32B, 16 H800 + 32 H20", LLAMA_32B, 16, 32, 64),
    ("70B, 16 H800 + 16 H20", LLAMA_70B, 16, 16, 64),
]

print(f"{'cluster':26s} {'uniform(best)':>14s} {'hetu(hetero)':>13s} {'speedup':>8s}")
for name, model, n800, n20, gbs in CASES:
    cluster = paper_cluster(n800, n20)
    ranks = list(range(n800 + n20))
    _, t_uni = best_uniform(cluster, model, ranks, gbs, 4096)
    strat = HETU_STRATEGIES[(model.name, n800, n20)]()
    t_het = step_time(cluster, model, strat, 4096)
    print(f"{name:26s} {t_uni:13.2f}s {t_het:12.2f}s {t_uni / t_het:7.2f}x")

print("""
Matches the paper's §7.1 finding: on heterogeneous clusters the uniform
systems bottleneck on the slowest device class, while HSPMD's asymmetric
stage/TP assignment keeps both device classes busy.""")
