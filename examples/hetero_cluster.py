"""Heterogeneous-cluster training comparison (paper §7.1, Fig 13).

Compares the best *uniform* strategy (what DeepSpeed/Megatron can express)
against Hetu's heterogeneous strategies (paper Appendix A.2, Table 5) on
the paper's H800+H20 clusters, using the calibrated cost model — then
exports one Table 5 strategy through ``repro.api`` and compiles its
per-layer weight program (grad-sync comm plans + migration cost).

    PYTHONPATH=src python examples/hetero_cluster.py
"""

from repro import api
from repro.core.costmodel import (LLAMA_32B, LLAMA_70B, best_uniform,
                                  paper_cluster, step_time)
from repro.scenarios.hetero import (HETU_STRATEGIES, layer_weight_shapes,
                                    to_api_strategy)

CASES = [
    ("32B, 16 H800 + 16 H20", LLAMA_32B, 16, 16, 64),
    ("32B, 16 H800 + 32 H20", LLAMA_32B, 16, 32, 64),
    ("70B, 16 H800 + 16 H20", LLAMA_70B, 16, 16, 64),
]

print(f"{'cluster':26s} {'uniform(best)':>14s} {'hetu(hetero)':>13s} {'speedup':>8s}")
for name, model, n800, n20, gbs in CASES:
    cluster = paper_cluster(n800, n20)
    ranks = list(range(n800 + n20))
    _, t_uni = best_uniform(cluster, model, ranks, gbs, 4096)
    strat = HETU_STRATEGIES[(model.name, n800, n20)]()
    t_het = step_time(cluster, model, strat, 4096)
    print(f"{name:26s} {t_uni:13.2f}s {t_het:12.2f}s {t_uni / t_het:7.2f}x")

# --- the same Table 5 strategies as repro.api objects -----------------------
print("\n=== Table 5 strategies through repro.api ===")
model = LLAMA_32B
shapes = layer_weight_shapes(model)
hetu = to_api_strategy("hetu-32b", HETU_STRATEGIES[(model.name, 16, 16)](),
                       model)
uniform, _ = best_uniform(paper_cluster(16, 16), model, list(range(32)),
                          64, 4096)
uni = to_api_strategy("uniform-32b", uniform, model)

prog = api.Program(api.weights_graph(shapes), [hetu, uni])
plan = prog.compile("hetu-32b")
print(f"hetu-32b weight placement: {len(plan.devices)} devices, "
      f"layer0 -> {plan.graph.tensors['layer0'].annots[0]}")

# cost of switching uniform -> hetu mid-run (fused BSR, paper §6.2)
tensors = [(n, uni.annots[n], hetu.annots[n], shapes[n], 2)
           for n in shapes]
report = api.estimate_switch(tensors)
print(f"uniform -> hetu switch: {report.summary()}")

print("""
Matches the paper's §7.1 finding: on heterogeneous clusters the uniform
systems bottleneck on the slowest device class, while HSPMD's asymmetric
stage/TP assignment keeps both device classes busy.""")
