"""Quickstart: HSPMD annotations, communication resolution, and a short
real training run — the paper's abstractions end to end in two minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

# --- 1. HSPMD annotations (paper §3) ---------------------------------------
from repro.core.annotations import DS, DUP, HSPMD, PARTIAL, spmd

print("=== 1. HSPMD annotations ===")
# classical SPMD (HSize=1): tensor split over 4 devices
flat = spmd([0, 1, 2, 3], DS({0: 4}))
# heterogeneous: two subgroups with different internal sharding,
# batch split 3:1 across them (a fast pair and a slow solo device)
hetero = HSPMD(dgs=[[0, 1], [2]], dss=[DS({1: 2}), DS({})],
               hdim=0, hsplits=[3, 1])
print("flat  :", flat)
print("hetero:", hetero)
shape = (16, 8)
for dev in (0, 2):
    print(f"  device {dev} holds box {hetero.device_box(dev, shape)}")

# --- 2. hierarchical communication resolution (paper §4) --------------------
from repro.core.comm_resolve import resolve
from repro.core.simulator import roundtrip_check

print("\n=== 2. communication resolution ===")
plan = resolve(flat, hetero, shape)
print(plan.describe())
value = np.random.default_rng(0).normal(size=shape)
roundtrip_check(value, flat, hetero, plan)  # numerically exact
print("numerical roundtrip: OK")

# --- 3. the gradient-sync pattern of heterogeneous DP (Fig 17) -------------
src = HSPMD(dgs=[[0, 1], [2]], dss=[DS({1: 2}), DS({})], hdim=PARTIAL)
dst = HSPMD(dgs=[[0, 1], [2]], dss=[DS({1: 2}), DS({})], hdim=DUP)
plan = resolve(src, dst, shape)
print("hetero-DP grad sync ->", plan.kind)

# --- 4. a short REAL training run (reduced Qwen2 config) -------------------
print("\n=== 3. training a reduced model ===")
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

cfg = get_config("qwen2-1.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=10),
                                num_microbatches=2))
rng = np.random.default_rng(0)
losses = []
for i in range(30):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 128)), jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'improving' if losses[-1] < losses[0] else 'NOT improving'})")
