"""Quickstart: the `repro.api` front door — Strategy -> Program ->
Session with pluggable executors, plus a short real training run.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro import api

# --- 1. HSPMD annotations (paper §3) ---------------------------------------
print("=== 1. HSPMD annotations ===")
# classical SPMD (HSize=1): tensor split over 4 devices
flat = api.spmd([0, 1, 2, 3], api.DS({0: 4}))
# heterogeneous: two subgroups with different internal sharding,
# batch split 3:1 across them (a fast pair and a slow solo device)
hetero = api.HSPMD(dgs=[[0, 1], [2]], dss=[api.DS({1: 2}), api.DS({})],
                   hdim=0, hsplits=[3, 1])
print("flat  :", flat)
print("hetero:", hetero)
shape = (16, 8)
for dev in (0, 2):
    print(f"  device {dev} holds box {hetero.device_box(dev, shape)}")

# --- 2. a single-device program + two named strategies ----------------------
print("\n=== 2. Strategy -> Program -> CompiledPlan ===")
g = api.Graph()
g.placeholder("X", (16, 32))
g.parameter("W1", (32, 24))
h = g.relu(g.dot(g.tensors["X"], g.tensors["W1"], name="H0"), name="H")
g.comm(h, name="H2")          # annotation point: strategies re-shard here
g.parameter("W2", (24, 8))
g.dot(g.tensors["H2"], g.tensors["W2"], name="Y")

# strategy A: TP stage on devices 0-3, pipeline hop to row-split 4-7
pipeline = api.Strategy("tp-pipeline", {
    "X": api.spmd([0, 1, 2, 3], api.DS({api.DUP: 4})),
    "W1": api.spmd([0, 1, 2, 3], api.DS({1: 4})),
    "H2": api.spmd([4, 5, 6, 7], api.DS({0: 4})),
    "W2": api.spmd([4, 5, 6, 7], api.DS({api.DUP: 4})),
})
# strategy B: pure data parallelism on devices 0-3
dataparallel = api.Strategy("dp", {
    "X": api.spmd([0, 1, 2, 3], api.DS({0: 4})),
    "W1": api.spmd([0, 1, 2, 3], api.DS({api.DUP: 4})),
    "H2": api.spmd([0, 1, 2, 3], api.DS({0: 4})),
    "W2": api.spmd([0, 1, 2, 3], api.DS({api.DUP: 4})),
})
prog = api.Program(g, [pipeline, dataparallel])
plan = prog.compile("tp-pipeline")
print(plan.describe())
print("device 0 runs:", [i.kind for i in plan.exec_items(0)])
print("device 5 runs:", [i.kind for i in plan.exec_items(5)])

# --- 3. Session: execute + restart-free strategy switch ---------------------
print("\n=== 3. Session.run + Session.switch ===")
rng = np.random.default_rng(0)
xv = rng.normal(size=(16, 32)).astype(np.float32)
w1v = rng.normal(size=(32, 24)).astype(np.float32)
w2v = rng.normal(size=(24, 8)).astype(np.float32)

sess = api.Session(prog, "tp-pipeline", executor=api.SimulatorExecutor())
sess.load({"W1": w1v, "W2": w2v})
out = sess.run({"X": xv})
want = np.maximum(xv @ w1v, 0) @ w2v
np.testing.assert_allclose(out.value("Y"), want, atol=1e-5)
print("numerical roundtrip: OK (executor:", sess.executor.name + ")")

report = sess.switch("dp")    # fused-BSR weight migration, no restart
print("switched tp-pipeline -> dp:", report.summary())
out = sess.run({"X": xv})
np.testing.assert_allclose(out.value("Y"), want, atol=1e-5)
print("post-switch output identical: OK")

# --- 3b. microbatched pipeline execution (1F1B / GPipe) ---------------------
print("\n=== 3b. pipeline schedules ===")
sess.switch("tp-pipeline")    # back onto the 2-stage pipeline strategy
out = sess.run({"X": xv}, num_microbatches=4, schedule="1f1b")
np.testing.assert_allclose(out.value("Y"), want, atol=1e-5)
print(out.schedule.describe())
print("stats:", out.stats.summary())
gp = sess.run({"X": xv}, num_microbatches=4, schedule="gpipe")
np.testing.assert_allclose(gp.value("Y"), want, atol=1e-5)
print("gpipe peak in-flight:",
      [gp.schedule.peak_in_flight(s) for s in range(gp.schedule.n_stages)],
      "vs 1f1b:",
      [out.schedule.peak_in_flight(s) for s in range(out.schedule.n_stages)])

# the gradient-sync pattern of heterogeneous DP (Fig 17) still one call:
src = api.HSPMD(dgs=[[0, 1], [2]], dss=[api.DS({1: 2}), api.DS({})],
                hdim=api.PARTIAL)
dst = api.HSPMD(dgs=[[0, 1], [2]], dss=[api.DS({1: 2}), api.DS({})],
                hdim=api.DUP)
print("hetero-DP grad sync ->", api.resolve(src, dst, shape).kind)

# --- 4. a short REAL training run (reduced Qwen2 config) -------------------
print("\n=== 4. training a reduced model ===")
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

cfg = get_config("qwen2-1.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=10),
                                num_microbatches=2))
rng = np.random.default_rng(0)
losses = []
for i in range(30):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 128)), jnp.int32)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
    params, opt, m = step(params, opt, batch)
    losses.append(float(m["loss"]))
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'improving' if losses[-1] < losses[0] else 'NOT improving'})")
