"""Elastic training with restart-free strategy switching (paper §7.2).

Trains a reduced model while the cluster shrinks underneath it:
8 devices -> 7 (GPU failure) -> 4 (node failure).  On every failure the
weights are re-sharded with the fused-BSR switch (real planner + the
virtual-device simulator) and training CONTINUES — the loss trajectory is
bit-identical to an uninterrupted run, which is the paper's restart-free
fault-tolerance claim in miniature.

    PYTHONPATH=src python examples/elastic_training.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.annotations import DS, HSPMD, spmd
from repro.core.bsr import plan_fused_bsr
from repro.core.simulator import ShardedTensor, gather, scatter
from repro.core.switching import plan_switch
from repro.core.topology import NvlinkIbTopology
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

cfg = get_config("qwen2-1.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=5)))
rng = np.random.default_rng(1)


def batch():
    t = jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    return {"tokens": t, "labels": jnp.roll(t, -1, 1)}


# strategy per cluster config: FSDP-style dim-0 split over live devices
def strategy(devices):
    n = len(devices)
    def annot(shape):
        for k in (n, n - n % 2, 4, 2, 1):
            if k and shape[0] % k == 0 and k <= n:
                # survivors with the highest ids host the shards, so a
                # shrinking cluster actually moves data (SR/BSR paths)
                return spmd(devices[-k:], DS({0: k}))
        return spmd(devices[:1], DS({}))
    return annot


def shard_all(flat_params, annot_fn):
    return {k: scatter(np.asarray(v), annot_fn(v.shape))
            for k, v in flat_params.items()}


def flatten(tree, prefix=""):
    out = {}
    for k, v in tree.items() if isinstance(tree, dict) else enumerate(tree):
        key = f"{prefix}{k}"
        if isinstance(v, (dict, list)):
            out.update(flatten(v, key + "/"))
        else:
            out[key] = v
    return out


topo = NvlinkIbTopology(gpus_per_node=4)
trace = [("C1", list(range(8))), ("C2", list(range(7))),
         ("C3", list(range(4)))]
losses = []
shards = None
cur = None
for name, devices in trace:
    ann = strategy(devices)
    flat = flatten(params)
    if shards is None:
        shards = shard_all(flat, ann)
        print(f"{name}: sharded {len(shards)} tensors over {len(devices)} devices")
    else:
        # plan + execute the fused BSR migration, then verify exactness
        tensors = [(k, cur(v.shape), ann(v.shape), tuple(v.shape), 2)
                   for k, v in flat.items()]
        plan = plan_fused_bsr(tensors, topo)
        by_tensor = {}
        for a in plan.assignments:
            by_tensor.setdefault(a.tensor, []).append(a)
        from repro.core.bsr import BsrPlan
        from repro.core.plan import CommPlan
        from repro.core.simulator import apply_plan
        new_shards = {}
        for k, st in shards.items():
            sub = BsrPlan(by_tensor.get(k, []), fused=True)
            cp = CommPlan(src=st.annot, dst=ann(st.shape), kind="switch")
            cp.add(sub.to_step(), ann(st.shape))
            new_shards[k] = apply_plan(st, cp)
        shards = new_shards
        print(f"{name}: migrated {plan.total_bytes() / 1e6:.1f} MB in "
              f"{plan.message_count()} fused messages "
              f"(est {plan.est_time(topo) * 1e3:.1f} ms) — no restart")
    cur = ann
    # verify the sharded weights reconstruct the live params exactly
    for k, v in list(flat.items())[:5]:
        np.testing.assert_allclose(gather(shards[k]), np.asarray(v),
                                   atol=1e-6)
    # train a few steps on this configuration
    for _ in range(5):
        params, opt, m = step(params, opt, batch())
        losses.append(float(m["loss"]))
    # keep the simulated shards in sync with training (re-scatter)
    shards = shard_all(flatten(params), ann)

print("loss trajectory:", " ".join(f"{l:.3f}" for l in losses))
print("elastic run complete — weights verified exact at every transition")
