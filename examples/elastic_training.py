"""Elastic training with restart-free strategy switching (paper §7.2).

Trains a reduced model while the cluster shrinks underneath it:
8 devices -> 7 (GPU failure) -> 4 (node failure).  On every failure a
``repro.api.Session`` switches the weight-placement strategy — the
fused-BSR planner + virtual-device simulator behind one
``session.switch`` call — and training CONTINUES with bit-identical
loss trajectory, the paper's restart-free fault-tolerance claim in
miniature.

    PYTHONPATH=src python examples/elastic_training.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs import get_config
from repro.core.topology import NvlinkIbTopology
from repro.models.model import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.steps import build_train_step

cfg = get_config("qwen2-1.5b").reduced()
params = init_params(jax.random.PRNGKey(0), cfg)
opt = init_opt_state(params)
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=5)))
rng = np.random.default_rng(1)


def batch():
    t = jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)), jnp.int32)
    return {"tokens": t, "labels": jnp.roll(t, -1, 1)}


def flatten(tree, prefix=""):
    out = {}
    for k, v in tree.items() if isinstance(tree, dict) else enumerate(tree):
        key = f"{prefix}{k}"
        if isinstance(v, (dict, list)):
            out.update(flatten(v, key + "/"))
        else:
            out[key] = v
    return out


topo = NvlinkIbTopology(gpus_per_node=4)
trace = [("C1", list(range(8))), ("C2", list(range(7))),
         ("C3", list(range(4)))]

# one weights-only Program; one FSDP-style strategy per cluster config
flat = flatten(params)
shapes = {k: tuple(np.asarray(v).shape) for k, v in flat.items()}
strategies = [api.data_parallel_strategy(name, devices, shapes,
                                         topology=topo)
              for name, devices in trace]
prog = api.Program(api.weights_graph(shapes), strategies)

losses = []
sess = None
for name, devices in trace:
    if sess is None:
        sess = api.Session(prog, name, topology=topo)
        sess.load({k: np.asarray(v) for k, v in flat.items()})
        print(f"{name}: sharded {len(shapes)} tensors over "
              f"{len(devices)} devices")
    else:
        # ONE call replaces the old hand-rolled fused-BSR block
        report = sess.switch(name)
        print(f"{name}: migrated {report.total_bytes / 1e6:.1f} MB in "
              f"{report.message_count} fused messages "
              f"(est {report.est_transfer_seconds * 1e3:.1f} ms) "
              f"— no restart")
    # verify the sharded weights reconstruct the live params exactly
    for k, v in list(flat.items())[:5]:
        np.testing.assert_allclose(sess.weight_value(k), np.asarray(v),
                                   atol=1e-6)
    # train a few steps on this configuration
    for _ in range(5):
        params, opt, m = step(params, opt, batch())
        losses.append(float(m["loss"]))
    # keep the simulated shards in sync with training (re-load)
    flat = flatten(params)
    sess.load({k: np.asarray(v) for k, v in flat.items()})

print("loss trajectory:", " ".join(f"{l:.3f}" for l in losses))
print("elastic run complete — weights verified exact at every transition")
